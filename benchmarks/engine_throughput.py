"""Engine-throughput benchmark: simulator requests/sec across engines.

The scan engine is the product's hot loop — every Figure 2/3 point, policy
grid, capacity sweep, and tail-latency table replays millions of requests
through the per-chunk request path. This benchmark plants the
``BENCH_engine_throughput.json`` trendline later PRs defend:

  * **grid rows** — warm-run ``run_scenario`` throughput in simulated
    requests/sec across engine × chunk-replay backend × daemon_interval ×
    num_keys (× policy, × telemetry on/off).
  * **speedup rows** — the same configs replayed through a faithful
    in-file replica of the PRE-fusion engine (``_legacy_simulate``: four
    separate latency passes, per-chunk O(K·N) occupancy for every policy,
    the telemetry histogram as a separate dispatch), so the fusion win is
    measurable from a single post-PR checkout.
  * **acceptance row** (``--acceptance``) — the ISSUE-5 criterion: warm
    ``run_scenario`` with telemetry on, wan5 topology, skewed traffic,
    1M requests, at the paper's access density (100 accesses/key ⇒
    num_keys = num_requests/100) must beat the pre-fusion engine ≥ 2x.

Methodology: sim-requests/sec = num_requests / wall-clock of one warm
``run_scenario`` call (compile + cache warmup excluded; median of
``--repeats`` (default 5) timed calls is the recorded trendline number).
Speedup ratios divide the per-side *minima* instead — contention noise on
shared runners is strictly additive, so min is the robust estimator of
true program cost (see ``_measure``). Timed work includes trace
generation and host-side result/trace materialisation, exactly what
every driver pays.

Since the scale-out fabric (ISSUE 7) the grid also measures
``trace_mode="streamed"`` rows for the scan engine (in-scan trace
generation, bit-exact with the materialised path), every row records
``peak_live_bytes`` — the analytic peak live-buffer footprint (trace
window + per-key state planes, the O(requests) → O(chunk + keys/shard)
memory win as a tracked column — see ``_peak_live_bytes``), and
``--trendline`` adds the multi-device scaling trendline: one subprocess
per device count (``XLA_FLAGS=--xla_force_host_platform_device_count=S``
must be set before jax initialises, hence the fresh interpreter per
point) runs the key-sharded streamed engine — routing tier off AND on in
that SAME subprocess — and reports requests/sec, ``scaling_vs_1shard``,
and ``routing_on_off_ratio`` (the directory tier's wall-clock cost
multiple, a machine-independent ratio since PR 8). The spec-scale run targets 100M+ requests over
10⁷ keys (``--trendline-requests 100000000 --trendline-keys 10000000``);
the checked-in baseline records a CI-tractable configuration of the same
shape. ``--scale-acceptance`` times one ≥10M-request streamed run on a
single device (the run the materialised path cannot fit at accelerator
HBM scale).

``--baseline PATH`` (default: the checked-in
``benchmarks/baselines/BENCH_engine_throughput.json``) warns —
``WARNING,engine_throughput_regression,...`` lines — when any matching grid
row regresses more than 20%. Absolute requests/sec warnings never fail the
job (wall-clock noise across runners makes that gate flaky), but
``--fail-on-regression`` promotes the *ratio* warnings to a hard nonzero
exit: fused and legacy engines run on the same box, so the
``speedup_vs_legacy`` ratio is machine-independent and a >20% drop there is
a genuine code-path regression, not runner noise — and the same logic
covers the trendline's sharded-vs-single-device ``scaling_vs_1shard``
ratios (both sides of that ratio also share one box).

Note on ``--backends pallas`` off-TPU: the Mosaic kernel runs in interpret
mode on CPU (a correctness/compile-path row, orders of magnitude slower
than compiled code); perf rows for the pallas backend are only meaningful
on a real TPU.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    WAN5_WORKLOAD_KWARGS,
    banner,
    emit,
    write_bench_json,
)
from repro.core.metadata import record_accesses
from repro.core.policy import (
    PolicyContext,
    parse_policy,
    policy_masked_step,
    split_policy,
)
from repro.kvsim import (
    AttributionConfig,
    FlightRecorderConfig,
    RoutingConfig,
    SimResult,
    TelemetryConfig,
    WorkloadConfig,
    run_scenario,
    wan5_cluster,
)
from repro.kvsim.simulate import (
    _chunk_latency,
    _initial_hosts,
    _node_occupancy,
    _seed_store,
)
from repro.kvsim.telemetry import (
    TelemetryLeaves,
    build_trace,
    chunk_histogram,
    normalize_telemetry,
)
from repro.kvsim.workload import generate_trace

DEFAULT_BASELINE = os.path.join(
    os.path.dirname(__file__), "baselines", "BENCH_engine_throughput.json"
)


# ---------------------------------------------------------------------------
# The pre-fusion engine, preserved verbatim as the speedup baseline.
# ---------------------------------------------------------------------------


def _legacy_simulate(
    keys, nodes, is_read, natural, object_bytes, params, *,
    cluster, policy, daemon_interval, telemetry=None,
):
    """The PRE-ISSUE-5 scan body: separate read/write/hit/busy passes over
    [B, N] intermediates, the O(K·N) occupancy sample paid per chunk for
    EVERY policy (including static maps that never change), and the
    telemetry histogram folded by a separate dispatch after the latency
    pass. Kept verbatim so ``speedup_vs_legacy`` measures exactly what the
    fusion + hoist bought."""
    r = keys.shape[0]
    num_keys = natural.shape[0]
    n = cluster.num_nodes
    rtt = cluster.rtt_matrix()
    obj = jnp.asarray(object_bytes, jnp.float32)
    capacity = (
        cluster.capacity_vector() if cluster.has_finite_capacity else None
    )
    ctx = PolicyContext(
        rtt=rtt, object_bytes=obj, capacity_bytes=capacity, params=params
    )
    num_chunks = -(-r // daemon_interval)
    pad = num_chunks * daemon_interval - r

    def chunked(x):
        if pad:
            x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
        return x.reshape(num_chunks, daemon_interval)

    xs = (
        jnp.arange(num_chunks, dtype=jnp.int32),
        chunked(keys), chunked(nodes), chunked(is_read),
        (jnp.arange(num_chunks * daemon_interval) < r).reshape(
            num_chunks, daemon_interval
        ),
    )
    store = _seed_store(
        _initial_hosts(natural, num_keys, n, policy.initial_placement),
        num_keys, n,
    )
    pstate = policy.init(store, ctx)
    zero = jnp.float32(0.0)
    init = (
        store, pstate, jnp.zeros((n,), jnp.float32), zero, zero, zero, zero,
        zero, zero, zero, _node_occupancy(store.hosts, obj),
    )

    def body(carry, x):
        (store, pstate, busy, lat_sum, hits, reads, repl, drop, evic,
         cap_evic, peak) = carry
        c, ck, cn, cr, cv = x
        lat, read_hits = _chunk_latency(
            store.hosts, ck, cn, cr, rtt, cluster, policy.read_mode
        )
        lat = jnp.where(cv, lat, 0.0)
        chunk_lat = jnp.sum(lat)
        chunk_hits = jnp.sum((read_hits & cv).astype(jnp.float32))
        chunk_reads = jnp.sum((cr & cv).astype(jnp.float32))
        busy = busy.at[cn].add(lat)
        lat_sum = lat_sum + chunk_lat
        hits = hits + chunk_hits
        reads = reads + chunk_reads
        occ = _node_occupancy(store.hosts, obj)  # paid per chunk, always
        peak = jnp.maximum(peak, occ)
        zero = jnp.float32(0.0)
        chunk_moves = (zero, zero, zero, zero)
        if policy.is_active:
            store = record_accesses(store, ck, cn, now=c, valid=cv)
            stats, pstate, store = policy_masked_step(
                policy, pstate, store, c, (c % policy.period) == 0, ctx
            )
            repl, drop = repl + stats.adds, drop + stats.drops
            evic = evic + stats.expiry_evictions
            cap_evic = cap_evic + stats.capacity_evictions
            chunk_moves = (
                stats.adds, stats.drops, stats.expiry_evictions,
                stats.capacity_evictions,
            )
        if telemetry is None:
            ys = None
        else:
            w = cv.astype(jnp.float32)
            ys = TelemetryLeaves(
                hist=chunk_histogram(
                    lat, cn * 2 + cr.astype(jnp.int32), w, telemetry, n
                ),
                hits=chunk_hits, reads=chunk_reads, lat_sum=chunk_lat,
                count=jnp.sum(w), adds=chunk_moves[0], drops=chunk_moves[1],
                expiry_evictions=chunk_moves[2],
                capacity_evictions=chunk_moves[3], occupancy=occ,
            )
        return (
            store, pstate, busy, lat_sum, hits, reads, repl, drop, evic,
            cap_evic, peak,
        ), ys

    (_, _, busy, lat_sum, hits, reads, repl, drop, evic, cap_evic, peak), ys = (
        jax.lax.scan(body, init, xs)
    )
    makespan_ms = jnp.max(busy)
    return (
        r / (makespan_ms / 1000.0), hits / jnp.maximum(reads, 1.0),
        lat_sum / r, busy, repl, drop, evic, cap_evic, peak,
    ), ys


_legacy_simulate_jit = partial(
    jax.jit, static_argnames=("cluster", "policy", "daemon_interval", "telemetry")
)(_legacy_simulate)


def legacy_run_scenario(workload, cluster, policy, seed=0,
                        daemon_interval=1000, telemetry=None):
    """``run_scenario``-equivalent driver over the pre-fusion engine (same
    host-side work: trace generation, result + trace materialisation)."""
    policy = policy.resolve(workload.num_nodes)
    policy.validate(workload.num_nodes)
    static, params = split_policy(policy)
    telemetry = normalize_telemetry(telemetry)
    trace = generate_trace(workload, seed)
    leaves, telem = _legacy_simulate_jit(
        trace.keys, trace.nodes, trace.is_read, trace.natural_node,
        trace.object_bytes, params, cluster=cluster, policy=static,
        daemon_interval=daemon_interval, telemetry=telemetry,
    )
    tput, hit, mean_lat, busy, repl, drop, evic, cap_evic, peak = leaves
    result = SimResult(
        throughput_ops_s=float(tput), hit_rate=float(hit),
        mean_latency_ms=float(mean_lat),
        node_busy_ms=np.asarray(busy, dtype=np.float64),
        replication_moves=float(repl), deletion_moves=float(drop),
        evictions=float(evic), capacity_evictions=float(cap_evic),
        peak_occupancy_bytes=np.asarray(peak, dtype=np.float64),
    )
    if telemetry is None:
        return result
    return result, build_trace(telem, telemetry)


# ---------------------------------------------------------------------------
# Measurement grid.
# ---------------------------------------------------------------------------


def _wan5_workload(num_requests, num_keys):
    return WorkloadConfig(
        num_requests=num_requests, num_keys=num_keys, skewed=True,
        read_fraction=0.9, **WAN5_WORKLOAD_KWARGS,
    )


def _peak_live_bytes(num_requests, num_keys, num_nodes, daemon_interval,
                     trace_mode, num_shards=1):
    """Analytic peak live-buffer bytes per device: trace window + per-key
    state planes (the buffers whose lifetime spans the scan — compiler
    scratch excluded, so this is the memory *model*, comparable across
    modes, not an allocator measurement).

    Trace rows cost 9 bytes (i32 key + i32 node + bool is_read): the whole
    ``[R]`` trace when materialised, one ``[daemon_interval]`` window when
    streamed. Per-key planes (sharded: ``K/S`` rows per device): natural +
    object_bytes (8 B) and the metadata store — access_counts ``[K, N]``
    i32, hosts ``[K, N]`` bool, last_access/live/home (9 B) — i.e.
    ``17 + 5·N`` bytes per key."""
    trace_rows = daemon_interval if trace_mode == "streamed" else num_requests
    keys_local = num_keys // num_shards
    return trace_rows * 9 + keys_local * (17 + 5 * num_nodes)


def _measure(engine, policy, workload, cluster, daemon_interval, telemetry,
             replay_backend, repeats, trace_mode="materialized",
             num_shards=1):
    """Warm wall-times of one full scenario run: ``(median_s, min_s)``.

    The JSON trendline records the median (the BENCH methodology); speedup
    ratios use the min of each side — on shared runners, contention noise
    is strictly additive, so the minimum is the robust estimator of the
    actual program cost and the ratio of minima is stable where a ratio of
    medians swings with whatever else the box is doing.
    """
    if engine == "legacy":
        fn = lambda: legacy_run_scenario(
            workload, cluster, policy, seed=0,
            daemon_interval=daemon_interval, telemetry=telemetry,
        )
    else:
        fn = lambda: run_scenario(
            workload, cluster, policy, seed=0,
            daemon_interval=daemon_interval, telemetry=telemetry,
            replay_backend=replay_backend, trace_mode=trace_mode,
            num_shards=num_shards,
        )
    for _ in range(2):  # compile + cache warmup
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times)), float(np.min(times))


def _row_key(row):
    return (
        row["engine"], row["policy"], row["replay_backend"],
        row["daemon_interval"], row["num_keys"], row["telemetry"],
        row["num_requests"], row.get("trace_mode", "materialized"),
        row.get("num_shards", 1),
    )


def _trendline_key(row):
    return (
        row["policy"], row["num_requests"], row["num_keys"],
        row["num_shards"],
    )


def _speedup_key(row):
    return (
        row["policy"], row["daemon_interval"], row["num_keys"],
        row["telemetry"], row["num_requests"],
    )


def check_regression(rows, baseline_path, threshold=0.20, speedups=None,
                     trendline=None):
    """Warn when a grid row is >20% below the checked-in baseline for the
    identical configuration; returns the warned rows, each tagged with
    ``"kind"`` so callers can gate selectively.

    Three signals: absolute requests/sec (``kind="throughput"``,
    machine-DEPENDENT — a slower runner trips it without any code change,
    so it only ever warns) and two machine-independent ratios
    ``--fail-on-regression`` hard-gates on: the ``speedup_vs_legacy``
    ratios (``kind="speedup"`` — fused and legacy engines run on the same
    box) and the trendline's ``scaling_vs_1shard`` ratios
    (``kind="scaling"`` — the sharded and 1-shard runs share one box too,
    so a drop means the sharded program itself regressed, e.g. a collective
    that grew from psum to all-gather). The trendline's
    ``routing_on_off_ratio`` (``kind="routing"`` — both sides share one
    process) gates in the OTHER direction: a ratio that GREW >20% over the
    baseline means the routing tier itself got more expensive."""
    if not os.path.exists(baseline_path):
        print(f"NOTE,no baseline at {baseline_path}, skipping regression check")
        return []
    with open(baseline_path) as fh:
        base_metrics = json.load(fh)["metrics"]
    base = {
        tuple(_row_key(r)): r["requests_per_s"]
        for r in base_metrics["rows"]
    }
    base_speedups = {
        tuple(_speedup_key(r)): r["speedup_vs_legacy"]
        for r in base_metrics.get("speedups", [])
    }
    base_trend = {
        tuple(_trendline_key(r)): r["scaling_vs_1shard"]
        for r in base_metrics.get("trendline", [])
    }
    base_routing = {
        tuple(_trendline_key(r)): r["routing_on_off_ratio"]
        for r in base_metrics.get("trendline", [])
        if "routing_on_off_ratio" in r
    }
    warned, matched = [], 0
    for row in trendline or []:
        ref = base_trend.get(tuple(_trendline_key(row)))
        if ref is not None and ref > 0 and row["num_shards"] > 1:
            ratio = row["scaling_vs_1shard"] / ref
            if ratio < 1.0 - threshold:
                warned.append({"kind": "scaling", **row})
                print(
                    "WARNING,engine_scaling_regression,"
                    f"shards={row['num_shards']}/nk={row['num_keys']},"
                    f"now={row['scaling_vs_1shard']:.2f}x,baseline={ref:.2f}x,"
                    f"ratio={ratio:.2f}",
                    flush=True,
                )
        ref = base_routing.get(tuple(_trendline_key(row)))
        if ref is not None and ref > 0 and "routing_on_off_ratio" in row:
            # Inverted sense: this ratio is a COST multiple (routing-on /
            # routing-off wall time), so growth is the regression.
            ratio = row["routing_on_off_ratio"] / ref
            if ratio > 1.0 + threshold:
                warned.append({"kind": "routing", **row})
                print(
                    "WARNING,engine_routing_overhead_regression,"
                    f"shards={row['num_shards']}/nk={row['num_keys']},"
                    f"now={row['routing_on_off_ratio']:.2f}x,"
                    f"baseline={ref:.2f}x,ratio={ratio:.2f}",
                    flush=True,
                )
    for row in speedups or []:
        ref = base_speedups.get(tuple(_speedup_key(row)))
        if ref is None or ref <= 0:
            continue
        ratio = row["speedup_vs_legacy"] / ref
        if ratio < 1.0 - threshold:
            warned.append({"kind": "speedup", **row})
            print(
                "WARNING,engine_speedup_regression,"
                f"{row['policy']}/di={row['daemon_interval']}/"
                f"nk={row['num_keys']},"
                f"now={row['speedup_vs_legacy']:.2f}x,baseline={ref:.2f}x,"
                f"ratio={ratio:.2f}",
                flush=True,
            )
    for row in rows:
        ref = base.get(tuple(_row_key(row)))
        if ref is None or ref <= 0:
            continue
        matched += 1
        ratio = row["requests_per_s"] / ref
        if ratio < 1.0 - threshold:
            warned.append({"kind": "throughput", **row})
            print(
                "WARNING,engine_throughput_regression,"
                f"{row['engine']}/{row['policy']}/{row['replay_backend']},"
                f"now={row['requests_per_s']:.0f},baseline={ref:.0f},"
                f"ratio={ratio:.2f} (absolute req/s — machine-dependent)",
                flush=True,
            )
    if matched == 0:
        # An all-clear here would hide a drifted sweep config silently
        # disabling the check.
        print(
            f"WARNING,engine_throughput_baseline_mismatch,0 of {len(rows)} "
            f"grid rows matched {baseline_path} — regression check did not "
            f"run (sweep config drifted from the checked-in baseline?)",
            flush=True,
        )
    elif not warned:
        print(
            f"NOTE,engine_throughput within 20% of baseline "
            f"({matched} rows compared)",
            flush=True,
        )
    return warned


# ---------------------------------------------------------------------------
# Multi-device trendline: one subprocess per virtual device count.
# ---------------------------------------------------------------------------

TRENDLINE_DEVICE_COUNTS = (1, 2, 4, 8)
_TRENDLINE_MARK = "TRENDLINE_ROW,"


# The routing-tier configuration the trendline prices: lagged publishes
# (ring buffer in the carry) with the unbounded/warm cache — the always-on
# consult + mis-route-pricing path every routed request pays. The bounded
# decay-LFU cache is deliberately excluded: its per-chunk [R, K] top_k (+
# all_gather when sharded) costs 3-20x and scales with the shard count,
# which would swamp the ratio with one optional feature's cost and make
# the 20%-growth CI gate flaky.
def _trendline_routing(num_keys):
    return RoutingConfig(publish_lag_chunks=8)


def _trendline_worker(num_shards, num_requests, num_keys, repeats,
                      daemon_interval, policy_spec):
    """Runs inside the forced-device-count subprocess: measure the streamed
    key-sharded run with the routing tier OFF and ON — both in this ONE
    subprocess (one backend init, one warmed cache per side; spawning a
    second interpreter per device count would double the dominant
    fixed cost and put the two sides of the ratio in different processes)
    — and print the row as a machine-readable line.

    ``routing_on_off_ratio`` divides per-side minima (routing-on /
    routing-off wall time, so 1.10 = the directory tier costs 10%): both
    sides share one box AND one process, so the ratio is machine-
    independent and regression-gateable like ``speedup_vs_legacy``."""
    pol = parse_policy(policy_spec)
    wl = _wan5_workload(num_requests, num_keys)
    cluster = wan5_cluster()
    med, lo = _measure(
        "scan", pol, wl, cluster, daemon_interval, None, "jax",
        repeats, trace_mode="streamed", num_shards=num_shards,
    )
    routed = cluster._replace(routing=_trendline_routing(num_keys))
    med_on, lo_on = _measure(
        "scan", pol, wl, routed, daemon_interval, None, "jax",
        repeats, trace_mode="streamed", num_shards=num_shards,
    )
    row = {
        "policy": policy_spec, "num_requests": num_requests,
        "num_keys": num_keys, "num_shards": num_shards,
        "daemon_interval": daemon_interval, "trace_mode": "streamed",
        "wall_s": med, "wall_s_min": lo,
        "requests_per_s": num_requests / med,
        "wall_s_routing_on": med_on, "wall_s_min_routing_on": lo_on,
        "requests_per_s_routing_on": num_requests / med_on,
        "routing_on_off_ratio": lo_on / lo,
        "peak_live_bytes": _peak_live_bytes(
            num_requests, num_keys, wl.num_nodes, daemon_interval,
            "streamed", num_shards,
        ),
    }
    print(_TRENDLINE_MARK + json.dumps(row), flush=True)


def run_trendline(device_counts, num_requests, num_keys, repeats,
                  daemon_interval, policy_spec):
    """The multi-device scaling trendline: re-invoke this script once per
    device count with ``--xla_force_host_platform_device_count`` forced in
    the child's environment (the flag is read once at backend init, so a
    fresh interpreter per point is the only correct spelling — same
    convention as the multi-rank tests).

    ``scaling_vs_1shard`` divides per-count minima (same robustness
    argument as ``speedup_vs_legacy``: both sides share one box)."""
    banner(
        f"trendline: streamed sharded engine, {num_requests:,} requests / "
        f"{num_keys:,} keys, device counts {tuple(device_counts)}"
    )
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env_base = dict(
        os.environ,
        PYTHONPATH=os.pathsep.join(
            [os.path.join(root, "src"), root,
             os.environ.get("PYTHONPATH", "")]
        ),
    )
    rows = []
    for s in device_counts:
        env = dict(
            env_base,
            XLA_FLAGS=f"--xla_force_host_platform_device_count={s}",
        )
        proc = subprocess.run(
            [
                sys.executable, os.path.abspath(__file__),
                "--trendline-worker", str(s),
                "--trendline-requests", str(num_requests),
                "--trendline-keys", str(num_keys),
                "--trendline-policy", policy_spec,
                "--repeats", str(repeats),
                "--daemon-intervals", str(daemon_interval),
            ],
            capture_output=True, text=True, env=env,
        )
        if proc.returncode != 0:
            raise SystemExit(
                f"FAIL,trendline worker (num_shards={s}) exited "
                f"{proc.returncode}:\n{proc.stdout}{proc.stderr}"
            )
        line = next(
            ln for ln in proc.stdout.splitlines()
            if ln.startswith(_TRENDLINE_MARK)
        )
        rows.append(json.loads(line[len(_TRENDLINE_MARK):]))
    base_min = rows[0]["wall_s_min"]
    for row in rows:
        row["scaling_vs_1shard"] = base_min / row["wall_s_min"]
        emit(
            "engine_trendline", round(row["requests_per_s"]), "req/s",
            num_shards=row["num_shards"], num_keys=row["num_keys"],
            num_requests=row["num_requests"],
            scaling_vs_1shard=round(row["scaling_vs_1shard"], 3),
            routing_on_off_ratio=round(row["routing_on_off_ratio"], 3),
            peak_live_mib=round(row["peak_live_bytes"] / 2**20, 1),
        )
    return rows


def run_scale_acceptance(num_requests, num_keys, daemon_interval,
                         policy_spec):
    """The ISSUE-7 streamed-scale criterion: a ≥10M-request streamed run
    completes on ONE device — peak live buffers O(daemon_interval + K), vs
    the O(R) trace the materialised path would have to hold."""
    banner(
        f"scale acceptance: streamed {num_requests:,}-request run, "
        "single device"
    )
    pol = parse_policy(policy_spec)
    wl = _wan5_workload(num_requests, num_keys)
    med, lo = _measure(
        "scan", pol, wl, wan5_cluster(), daemon_interval, None, "jax",
        repeats=1, trace_mode="streamed",
    )
    row = {
        "policy": policy_spec, "num_requests": num_requests,
        "num_keys": num_keys, "trace_mode": "streamed",
        "wall_s": med, "requests_per_s": num_requests / med,
        "peak_live_bytes": _peak_live_bytes(
            num_requests, num_keys, wl.num_nodes, daemon_interval,
            "streamed",
        ),
        "materialized_trace_bytes": _peak_live_bytes(
            num_requests, num_keys, wl.num_nodes, daemon_interval,
            "materialized",
        ),
        "passed": num_requests >= 10_000_000,
    }
    emit(
        "engine_scale_acceptance", round(row["requests_per_s"]), "req/s",
        num_requests=num_requests, num_keys=num_keys,
        peak_live_mib=round(row["peak_live_bytes"] / 2**20, 1),
        materialized_mib=round(row["materialized_trace_bytes"] / 2**20, 1),
    )
    print(
        f"ACCEPTANCE,{'PASS' if row['passed'] else 'FAIL'},streamed "
        f"{num_requests:,} requests in {med:.2f}s on one device "
        f"(live {row['peak_live_bytes'] / 2**20:.1f} MiB vs "
        f"{row['materialized_trace_bytes'] / 2**20:.1f} MiB materialised)",
        flush=True,
    )
    return row


def run_profile(profile_dir, num_requests, num_keys, daemon_interval,
                policy_spec, replay_backend="jax"):
    """``--profile``: phase timings + a ``jax.profiler`` trace capture.

    Times the three host-visible phases of one scenario — trace
    generation, cold compile, warm execute — then re-runs the warm
    program under ``jax.profiler.trace(profile_dir)`` so the scan-body
    ``jax.named_scope`` annotations (routing_prepass, contention_prepass,
    chunk_replay, attribution_*, flight_recorder, policy_step) land in a
    TensorBoard/Perfetto-loadable capture. Telemetry runs with
    attribution + flight recorder ON so every annotated phase is present
    in the program being profiled.
    """
    banner(f"profile: phase timings -> {profile_dir}")
    pol = parse_policy(policy_spec)
    wl = _wan5_workload(num_requests, num_keys)
    cluster = wan5_cluster()
    telem = TelemetryConfig(
        attribution=AttributionConfig(), flight=FlightRecorderConfig()
    )
    t0 = time.perf_counter()
    jax.block_until_ready(generate_trace(wl, 0).keys)
    t_trace = time.perf_counter() - t0
    fn = lambda: run_scenario(
        wl, cluster, pol, seed=0, daemon_interval=daemon_interval,
        telemetry=telem, replay_backend=replay_backend,
    )
    t0 = time.perf_counter()
    fn()
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    fn()
    t_warm = time.perf_counter() - t0
    os.makedirs(profile_dir, exist_ok=True)
    with jax.profiler.trace(profile_dir):
        fn()
    phases = {
        "trace_generation_s": t_trace,
        "cold_compile_and_run_s": t_cold,
        "warm_run_s": t_warm,
        "compile_overhead_s": t_cold - t_warm,
        "warm_requests_per_s": num_requests / t_warm,
    }
    for name, val in phases.items():
        emit("engine_profile", round(val, 4), name.rsplit("_", 1)[-1],
             phase=name, policy=policy_spec, backend=replay_backend)
    print(f"WROTE,{profile_dir} (jax.profiler capture; load in "
          f"TensorBoard's profile plugin or ui.perfetto.dev)", flush=True)
    return phases


def main(
    num_requests: int = 200_000,
    repeats: int = 5,
    daemon_intervals=(1000,),
    num_keys_grid=(1_000, 10_000),
    policy_specs=("replicated", "redynis"),
    backends=("jax",),
    engines=("scan", "legacy"),
    telemetry_modes=(True, False),
    trace_modes=("materialized", "streamed"),
    acceptance: bool = False,
    baseline: str | None = DEFAULT_BASELINE,
    policy=None,
    replay_backend: str | None = None,
    fail_on_regression: bool = False,
    trendline: bool = False,
    trendline_devices=TRENDLINE_DEVICE_COUNTS,
    trendline_requests: int = 2_000_000,
    trendline_keys: int = 200_000,
    trendline_policy: str = "redynis",
    scale_acceptance: bool = False,
    scale_requests: int = 10_000_000,
    scale_keys: int = 1_000_000,
    scale_policy: str = "replicated",
    profile_dir: str | None = None,
) -> dict:
    banner("engine_throughput: simulator requests/sec, fused vs pre-fusion")
    if replay_backend is not None:
        # benchmarks/run.py forwards a single --replay-backend; measure
        # that backend only.
        backends = (replay_backend,)
    if "jax" not in backends:
        # speedup_vs_legacy compares legacy/jax against scan/jax; without
        # a jax scan row the legacy timings would be dead weight.
        engines = tuple(e for e in engines if e != "legacy")
    cluster = wan5_cluster()
    telem_cfg = TelemetryConfig()
    rows, speedups = [], []
    t_start = time.perf_counter()

    candidates = [parse_policy(s) for s in policy_specs]
    if policy is not None:
        candidates.append(policy)

    for pol in candidates:
        label = getattr(type(pol), "name", type(pol).__name__)
        label = f"{label}:{pol.mode}" if hasattr(pol, "mode") else label
        for di in daemon_intervals:
            for nk in num_keys_grid:
                wl = _wan5_workload(num_requests, nk)
                for telem_on in telemetry_modes:
                    telem = telem_cfg if telem_on else None
                    times = {}
                    for engine in engines:
                        bkds = backends if engine == "scan" else ("jax",)
                        # Streamed trace generation exists only in the
                        # fused scan engine; the legacy replica predates it.
                        tms = (
                            trace_modes if engine == "scan"
                            else ("materialized",)
                        )
                        for bk in bkds:
                            for tm in tms:
                                med, lo = _measure(
                                    engine, pol, wl, cluster, di, telem, bk,
                                    repeats, trace_mode=tm,
                                )
                                if tm == "materialized":
                                    times[(engine, bk)] = lo
                                row = {
                                    "engine": engine, "policy": label,
                                    "replay_backend": bk,
                                    "daemon_interval": di,
                                    "num_keys": nk, "telemetry": telem_on,
                                    "num_requests": num_requests,
                                    "trace_mode": tm,
                                    "num_shards": 1,
                                    "wall_s": med,
                                    "wall_s_min": lo,
                                    "requests_per_s": num_requests / med,
                                    "peak_live_bytes": _peak_live_bytes(
                                        num_requests, nk, wl.num_nodes,
                                        di, tm,
                                    ),
                                }
                                rows.append(row)
                                emit(
                                    "engine_throughput",
                                    round(row["requests_per_s"]),
                                    "req/s",
                                    engine=engine, policy=label, backend=bk,
                                    daemon_interval=di, num_keys=nk,
                                    telemetry=int(telem_on), trace_mode=tm,
                                    wall_s=round(med, 4),
                                    peak_live_mib=round(
                                        row["peak_live_bytes"] / 2**20, 2
                                    ),
                                )
                    if ("legacy", "jax") in times and ("scan", "jax") in times:
                        speedup = times[("legacy", "jax")] / times[("scan", "jax")]
                        speedups.append({
                            "policy": label, "daemon_interval": di,
                            "num_keys": nk, "telemetry": telem_on,
                            "num_requests": num_requests,
                            "speedup_vs_legacy": speedup,
                        })
                        emit(
                            "engine_speedup", round(speedup, 2), "x",
                            policy=label, daemon_interval=di, num_keys=nk,
                            telemetry=int(telem_on),
                        )

    accept = None
    if acceptance:
        # ISSUE-5 acceptance: wan5, skewed, 1M requests, telemetry ON, the
        # paper's access density (100 accesses/key) held at scale. Both
        # daemon cadences are reported; speedups are ratios of per-side
        # minima (see _measure).
        banner("acceptance: 1M-request warm run_scenario vs pre-fusion engine")
        a_req = 1_000_000
        wl = _wan5_workload(a_req, a_req // 100)
        accept = {"num_requests": a_req, "num_keys": a_req // 100,
                  "telemetry": True, "rows": []}
        for di in (1000, 500):
            for spec in policy_specs:
                pol = parse_policy(spec)
                _, t_new = _measure("scan", pol, wl, cluster, di, telem_cfg,
                                    "jax", repeats)
                _, t_old = _measure("legacy", pol, wl, cluster, di, telem_cfg,
                                    "jax", repeats)
                speedup = t_old / t_new
                accept["rows"].append({
                    "policy": spec, "daemon_interval": di,
                    "fused_wall_s": t_new, "legacy_wall_s": t_old,
                    "fused_req_per_s": a_req / t_new,
                    "legacy_req_per_s": a_req / t_old,
                    "speedup_vs_legacy": speedup,
                })
                emit(
                    "engine_acceptance", round(speedup, 2), "x", policy=spec,
                    daemon_interval=di,
                    fused_req_per_s=round(a_req / t_new),
                    legacy_req_per_s=round(a_req / t_old),
                )
        best = max(v["speedup_vs_legacy"] for v in accept["rows"])
        accept["passed"] = best >= 2.0
        print(
            f"ACCEPTANCE,{'PASS' if accept['passed'] else 'FAIL'},"
            f"best_speedup={best:.2f}x (need >= 2x)",
            flush=True,
        )

    trend_rows = None
    if trendline:
        trend_rows = run_trendline(
            tuple(trendline_devices), trendline_requests, trendline_keys,
            repeats, daemon_intervals[0], trendline_policy,
        )
    profile_phases = None
    if profile_dir:
        profile_phases = run_profile(
            profile_dir, num_requests, num_keys_grid[0],
            daemon_intervals[0], policy_specs[0],
            replay_backend=backends[0],
        )
    scale_row = None
    if scale_acceptance:
        # A static policy by design: the criterion is the streamed-trace
        # MEMORY model (O(chunk + keys), policy-independent); an active
        # policy's O(K·N)-per-tick sweep would just drown the measurement.
        scale_row = run_scale_acceptance(
            scale_requests, scale_keys, daemon_intervals[0], scale_policy
        )

    warned = (
        check_regression(
            rows, baseline, speedups=speedups, trendline=trend_rows
        )
        if baseline else []
    )
    metrics = {
        "rows": rows,
        "speedups": speedups,
        "regressions": len(warned),
        "wall_time_s": time.perf_counter() - t_start,
    }
    if accept is not None:
        metrics["acceptance"] = accept
    if trend_rows is not None:
        metrics["trendline"] = trend_rows
    if scale_row is not None:
        metrics["scale_acceptance"] = scale_row
    if profile_phases is not None:
        metrics["profile"] = profile_phases
    write_bench_json(
        "engine_throughput", metrics,
        num_requests=num_requests, repeats=repeats,
        backend_platform=jax.default_backend(),
        topology="wan5", skewed=True, read_fraction=0.9,
    )
    if fail_on_regression:
        hard = [
            w for w in warned
            if w.get("kind") in ("speedup", "scaling", "routing")
        ]
        if hard:
            raise SystemExit(
                f"FAIL,engine_ratio_regression,{len(hard)} machine-"
                f"independent ratio(s) (fused-vs-legacy speedup, sharded-"
                f"vs-1-shard scaling, or routing-tier on/off overhead) "
                f">20% off baseline (see WARNING lines above)"
            )
    return metrics


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--num-requests", type=int, default=200_000)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--daemon-intervals", nargs="+", type=int, default=[1000])
    ap.add_argument("--num-keys", nargs="+", type=int, default=[1_000, 10_000])
    ap.add_argument(
        "--policies", nargs="+", default=["replicated", "redynis"],
        metavar="NAME[:k=v,...]",
    )
    ap.add_argument(
        "--backends", nargs="+", default=["jax"], choices=["jax", "pallas"],
        help="chunk-replay backends for the scan engine (pallas is "
        "interpret-mode off-TPU: correctness row, not a perf row)",
    )
    ap.add_argument(
        "--engines", nargs="+", default=["scan", "legacy"],
        choices=["scan", "legacy"],
    )
    ap.add_argument(
        "--telemetry", choices=["on", "off", "both"], default="both"
    )
    ap.add_argument(
        "--trace-modes", nargs="+", default=["materialized", "streamed"],
        choices=["materialized", "streamed"],
        help="trace generation modes for the scan engine (legacy is "
        "always materialized)",
    )
    ap.add_argument("--acceptance", action="store_true",
                    help="run the 1M-request ISSUE-5 acceptance comparison")
    ap.add_argument(
        "--trendline", action="store_true",
        help="measure the multi-device scaling trendline (one forced-"
        "device-count subprocess per point, streamed sharded engine)",
    )
    ap.add_argument(
        "--trendline-devices", nargs="+", type=int,
        default=list(TRENDLINE_DEVICE_COUNTS),
    )
    ap.add_argument("--trendline-requests", type=int, default=2_000_000)
    ap.add_argument("--trendline-keys", type=int, default=200_000)
    ap.add_argument("--trendline-policy", default="redynis")
    ap.add_argument(
        "--trendline-worker", type=int, metavar="NUM_SHARDS", default=None,
        help=argparse.SUPPRESS,  # internal: the per-device-count subprocess
    )
    ap.add_argument(
        "--scale-acceptance", action="store_true",
        help="time one >=10M-request streamed run on a single device "
        "(the ISSUE-7 memory-model criterion)",
    )
    ap.add_argument("--scale-requests", type=int, default=10_000_000)
    ap.add_argument("--scale-keys", type=int, default=1_000_000)
    ap.add_argument("--scale-policy", default="replicated")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="checked-in BENCH json to warn against "
                    "('' disables)")
    ap.add_argument(
        "--fail-on-regression", action="store_true",
        help="exit nonzero when a fused-vs-legacy speedup ratio regresses "
        ">20% vs the baseline (absolute req/s stays warn-only: it is "
        "machine-dependent)",
    )
    ap.add_argument(
        "--profile", metavar="DIR", default=None,
        help="also run one attribution+flight-on scenario under "
        "jax.profiler.trace(DIR) and report phase timings (the scan "
        "phases carry jax.named_scope annotations)",
    )
    args = ap.parse_args()
    if args.trendline_worker is not None:
        _trendline_worker(
            args.trendline_worker, args.trendline_requests,
            args.trendline_keys, args.repeats, args.daemon_intervals[0],
            args.trendline_policy,
        )
        raise SystemExit(0)
    main(
        num_requests=args.num_requests,
        repeats=args.repeats,
        daemon_intervals=tuple(args.daemon_intervals),
        num_keys_grid=tuple(args.num_keys),
        policy_specs=tuple(args.policies),
        backends=tuple(args.backends),
        engines=tuple(args.engines),
        telemetry_modes={
            "on": (True,), "off": (False,), "both": (True, False)
        }[args.telemetry],
        trace_modes=tuple(args.trace_modes),
        acceptance=args.acceptance,
        baseline=args.baseline or None,
        fail_on_regression=args.fail_on_regression,
        trendline=args.trendline,
        trendline_devices=tuple(args.trendline_devices),
        trendline_requests=args.trendline_requests,
        trendline_keys=args.trendline_keys,
        trendline_policy=args.trendline_policy,
        scale_acceptance=args.scale_acceptance,
        scale_requests=args.scale_requests,
        scale_keys=args.scale_keys,
        scale_policy=args.scale_policy,
        profile_dir=args.profile,
    )
