"""Latency attribution: per-policy component breakdown on the wan5 WAN.

The provenance experiment the attribution layer exists for: with the
queueing model AND the routing/directory tier enabled, every request's
latency decomposes into the 8-way component taxonomy
(``repro.kernels.chunk_replay.ref.COMPONENTS``), and the per-policy story
becomes visible — replicated pays its write-broadcast legs, remote pays
read RTT, redynis trades a transient routing-detour/directory-fetch cost
for a collapsing read_rtt share. Emits one row per (policy, component),
persists ``BENCH_attribution.json`` (rows + the component-sum-reconstructs
-total checks the trend dashboard gates on), and — with ``--trace-out`` —
exports a sampled-request Chrome trace (Perfetto-loadable) from the flight
recorder.
"""

from __future__ import annotations

import argparse
import time

from benchmarks.common import (
    WAN5_WORKLOAD_KWARGS,
    banner,
    dedupe_policies,
    emit,
    write_bench_json,
)
from repro.kvsim import (
    COMPONENTS,
    AttributionConfig,
    FlightRecorderConfig,
    RoutingConfig,
    ServiceConfig,
    TelemetryConfig,
    describe_policy,
    parse_policy,
    run_scenario,
    wan5_cluster,
    wan5_workload,
    write_chrome_trace,
)

DEFAULT_POLICIES = (
    "remote",
    "replicated",
    "redynis",
    "costgreedy",
)

# Both surcharge models on, so every component can be exercised: moderate
# queueing load, a 2-chunk-stale directory (active policies move keys, so
# their routers pay detours until the publish catches up), and a bounded
# router cache (cold keys miss and pay the home-node directory fetch).
SERVICE = ServiceConfig(serve_bytes_per_ms=128.0, capacity_factor=2.0)
ROUTING = RoutingConfig(publish_lag_chunks=2, cache_entries=256)


def main(
    num_requests: int = 30_000,
    read_fraction: float = 0.9,
    seed: int = 0,
    daemon_interval: int = 1000,
    policy_specs=DEFAULT_POLICIES,
    num_bins: int = 96,
    trace_out: str | None = None,
    trace_policy: str = "redynis",
    samples_per_chunk: int = 8,
) -> dict:
    banner("latency_attribution: component breakdown per policy (wan5)")
    cluster = wan5_cluster()._replace(service=SERVICE, routing=ROUTING)
    workload = wan5_workload(
        num_requests=num_requests,
        read_fraction=read_fraction,
        skewed=True,
        **{
            k: v
            for k, v in WAN5_WORKLOAD_KWARGS.items()
            if k != "num_nodes"
        },
    )
    telemetry = TelemetryConfig(
        num_bins=num_bins,
        attribution=AttributionConfig(num_bins=num_bins),
        flight=FlightRecorderConfig(samples_per_chunk=samples_per_chunk),
    )
    policies = dedupe_policies(
        [parse_policy(s) for s in policy_specs], cluster.num_nodes
    )
    trace_label = describe_policy(
        parse_policy(trace_policy).resolve(cluster.num_nodes)
    )
    t_start = time.perf_counter()
    rows, components, checks = [], {}, {}
    for policy in policies:
        label = describe_policy(policy.resolve(cluster.num_nodes))
        result, trace = run_scenario(
            workload,
            cluster,
            policy,
            seed=seed,
            daemon_interval=daemon_interval,
            telemetry=telemetry,
        )
        attr = trace.attribution
        comp_sum = sum(stats["mean_ms"] for stats in attr.values())
        # The headline invariant, gated by bench_trend: the per-request
        # component means must reconstruct the engine's mean latency.
        ok = abs(comp_sum - result.mean_latency_ms) <= 1e-3 * max(
            result.mean_latency_ms, 1.0
        )
        checks[f"component_sum_reconstructs_total/{label}"] = bool(ok)
        components[label] = {
            name: {
                "mean_ms": stats["mean_ms"],
                "share": stats["share"],
                "p50_ms": stats["p50"],
                "p99_ms": stats["p99"],
            }
            for name, stats in attr.items()
        }
        row = {
            "policy": label,
            "mean_latency_ms": result.mean_latency_ms,
            "hit_rate": result.hit_rate,
            "component_sum_ms": comp_sum,
        }
        for name in COMPONENTS:
            row[f"{name}_ms"] = attr[name]["mean_ms"]
        rows.append(row)
        top = max(
            (n for n in COMPONENTS if n != "service"),
            key=lambda n: attr[n]["mean_ms"],
        )
        emit(
            "latency_attribution",
            round(result.mean_latency_ms, 3),
            "mean_ms",
            policy=label,
            component_sum=round(comp_sum, 3),
            top_component=top,
            top_ms=round(attr[top]["mean_ms"], 3),
            detour_ms=round(attr["routing_detour"]["mean_ms"], 3),
            broadcast_ms=round(attr["write_broadcast"]["mean_ms"], 3),
        )
        if trace_out and label == trace_label:
            n_events = write_chrome_trace(trace.flight_records(), trace_out)
            print(f"WROTE,{trace_out} ({n_events} request events)")
    write_bench_json(
        "attribution",
        {
            "rows": rows,
            "components": components,
            "checks": checks,
            "wall_time_s": time.perf_counter() - t_start,
        },
        num_requests=num_requests,
        read_fraction=read_fraction,
        seed=seed,
        daemon_interval=daemon_interval,
        num_bins=num_bins,
        samples_per_chunk=samples_per_chunk,
        service=True,
        routing_publish_lag_chunks=ROUTING.publish_lag_chunks,
        routing_cache_entries=ROUTING.cache_entries,
    )
    return {"rows": rows, "components": components, "checks": checks}


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--num-requests", type=int, default=30_000)
    ap.add_argument("--read-fraction", type=float, default=0.9)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--daemon-interval", type=int, default=1000)
    ap.add_argument("--num-bins", type=int, default=96)
    ap.add_argument(
        "--policies", nargs="+", default=list(DEFAULT_POLICIES),
        metavar="NAME[:k=v,...]",
    )
    ap.add_argument(
        "--trace-out", metavar="PATH",
        help="write the flight-recorder Chrome trace (Perfetto-loadable) "
        "for --trace-policy here",
    )
    ap.add_argument("--trace-policy", default="redynis")
    ap.add_argument("--samples-per-chunk", type=int, default=8)
    args = ap.parse_args()
    main(
        num_requests=args.num_requests,
        read_fraction=args.read_fraction,
        seed=args.seed,
        daemon_interval=args.daemon_interval,
        policy_specs=tuple(args.policies),
        num_bins=args.num_bins,
        trace_out=args.trace_out,
        trace_policy=args.trace_policy,
        samples_per_chunk=args.samples_per_chunk,
    )
