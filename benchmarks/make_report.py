"""Fill EXPERIMENTS.md placeholders from the results JSONs.

Usage: PYTHONPATH=src python benchmarks/make_report.py
Replaces <!-- DRYRUN_TABLE --> and <!-- ROOFLINE_TABLE --> with generated
markdown; §Perf and figure sections are authored by hand from the logged
runs (benchmarks/results/perf/*.json).
"""

from __future__ import annotations

import glob
import json
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRYRUN = os.path.join(ROOT, "benchmarks", "results", "dryrun")
PERF = os.path.join(ROOT, "benchmarks", "results", "perf")

ARCH_ORDER = [
    "yi-9b", "qwen3-1.7b", "llama3.2-3b", "mistral-large-123b", "rwkv6-1.6b",
    "llava-next-34b", "recurrentgemma-2b", "whisper-base", "deepseek-moe-16b",
    "granite-moe-1b-a400m",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(path):
    with open(path) as f:
        return json.load(f)


def all_results():
    out = {}
    for p in glob.glob(os.path.join(DRYRUN, "*.json")):
        r = load(p)
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def fmt_mem(r):
    am = r.get("analytic_memory", {})
    raw = r["memory"]["peak_bytes_per_device"] / 1e9
    ana = am.get("total_bytes", 0) / 1e9
    fit = "yes" if am.get("fits_16GB") else "no"
    return f"{ana:.1f} ({raw:.1f} raw)", fit


def dryrun_table(res) -> str:
    lines = [
        "| arch | shape | 16×16 | 2×16×16 | per-chip GB (analytic/raw) | fits 16GB |",
        "|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r1 = res.get((a, s, "16x16"))
            r2 = res.get((a, s, "2x16x16"))
            if r1 is None:
                continue
            if r1.get("skipped"):
                lines.append(f"| {a} | {s} | skip¹ | skip¹ | — | — |")
                continue
            ok1 = "compiles" if r1.get("ok") else "FAIL"
            ok2 = "compiles" if (r2 and r2.get("ok")) else ("FAIL" if r2 else "?")
            memtxt, fit = fmt_mem(r1)
            lines.append(f"| {a} | {s} | {ok1} | {ok2} | {memtxt} | {fit} |")
    lines.append("")
    lines.append("¹ long_500k: full-attention archs skipped per assignment "
                 "(sub-quadratic only; see DESIGN.md).")
    return "\n".join(lines)


def roofline_table(res) -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | useful | roofline |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = res.get((a, s, "16x16"))
            if r is None or r.get("skipped") or not r.get("ok"):
                continue
            t = r["roofline"]
            lines.append(
                f"| {a} | {s} | {t['compute_s']:.4f} | {t['memory_s']:.4f} | "
                f"{t['collective_s']:.4f} | {t['dominant']} | "
                f"{t.get('useful_flops_frac', 0):.2f} | {t.get('roofline_frac', 0):.4f} |"
            )
    return "\n".join(lines)


def main() -> None:
    res = all_results()
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(path) as f:
        doc = f.read()
    doc = doc.replace("<!-- DRYRUN_TABLE -->", dryrun_table(res))
    doc = doc.replace("<!-- ROOFLINE_TABLE -->", roofline_table(res))
    with open(path, "w") as f:
        f.write(doc)
    print(f"EXPERIMENTS.md updated with {len(res)} cells")


if __name__ == "__main__":
    main()
