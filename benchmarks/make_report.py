"""Fill EXPERIMENTS.md placeholders from the results JSONs.

Usage: PYTHONPATH=src python benchmarks/make_report.py
Replaces <!-- DRYRUN_TABLE -->, <!-- ROOFLINE_TABLE --> and
<!-- TAIL_LATENCY_TABLE --> with generated markdown; §Perf and figure
sections are authored by hand from the logged runs
(benchmarks/results/perf/*.json). The tail-latency table is rebuilt from
``BENCH_tail_latency.json`` (searched in $BENCH_DIR, then the repo root)
whenever that artifact exists — re-run ``benchmarks/tail_latency.py`` then
this script to refresh the quantile columns in §Telemetry.
"""

from __future__ import annotations

import glob
import json
import os
import re

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRYRUN = os.path.join(ROOT, "benchmarks", "results", "dryrun")
PERF = os.path.join(ROOT, "benchmarks", "results", "perf")

ARCH_ORDER = [
    "yi-9b", "qwen3-1.7b", "llama3.2-3b", "mistral-large-123b", "rwkv6-1.6b",
    "llava-next-34b", "recurrentgemma-2b", "whisper-base", "deepseek-moe-16b",
    "granite-moe-1b-a400m",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(path):
    with open(path) as f:
        return json.load(f)


def all_results():
    out = {}
    for p in glob.glob(os.path.join(DRYRUN, "*.json")):
        r = load(p)
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def fmt_mem(r):
    am = r.get("analytic_memory", {})
    raw = r["memory"]["peak_bytes_per_device"] / 1e9
    ana = am.get("total_bytes", 0) / 1e9
    fit = "yes" if am.get("fits_16GB") else "no"
    return f"{ana:.1f} ({raw:.1f} raw)", fit


def dryrun_table(res) -> str:
    lines = [
        "| arch | shape | 16×16 | 2×16×16 | per-chip GB (analytic/raw) | fits 16GB |",
        "|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r1 = res.get((a, s, "16x16"))
            r2 = res.get((a, s, "2x16x16"))
            if r1 is None:
                continue
            if r1.get("skipped"):
                lines.append(f"| {a} | {s} | skip¹ | skip¹ | — | — |")
                continue
            ok1 = "compiles" if r1.get("ok") else "FAIL"
            ok2 = "compiles" if (r2 and r2.get("ok")) else ("FAIL" if r2 else "?")
            memtxt, fit = fmt_mem(r1)
            lines.append(f"| {a} | {s} | {ok1} | {ok2} | {memtxt} | {fit} |")
    lines.append("")
    lines.append("¹ long_500k: full-attention archs skipped per assignment "
                 "(sub-quadratic only; see DESIGN.md).")
    return "\n".join(lines)


def roofline_table(res) -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | useful | roofline |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = res.get((a, s, "16x16"))
            if r is None or r.get("skipped") or not r.get("ok"):
                continue
            t = r["roofline"]
            lines.append(
                f"| {a} | {s} | {t['compute_s']:.4f} | {t['memory_s']:.4f} | "
                f"{t['collective_s']:.4f} | {t['dominant']} | "
                f"{t.get('useful_flops_frac', 0):.2f} | {t.get('roofline_frac', 0):.4f} |"
            )
    return "\n".join(lines)


def find_tail_latency_json():
    """BENCH_tail_latency.json from $BENCH_DIR, the repo root, else the
    checked-in baselines directory."""
    dirs = [
        os.environ.get("BENCH_DIR"),
        ROOT,
        os.path.join(ROOT, "benchmarks", "baselines"),
    ]
    for d in filter(None, dirs):
        p = os.path.join(d, "BENCH_tail_latency.json")
        if os.path.exists(p):
            return p
    return None


TAIL_BEGIN = "<!-- TAIL_LATENCY_TABLE_BEGIN -->"
TAIL_END = "<!-- TAIL_LATENCY_TABLE_END -->"
CONTENTION_BEGIN = "<!-- CONTENTION_TAIL_TABLE_BEGIN -->"
CONTENTION_END = "<!-- CONTENTION_TAIL_TABLE_END -->"
TRENDLINE_BEGIN = "<!-- SCALE_TRENDLINE_TABLE_BEGIN -->"
TRENDLINE_END = "<!-- SCALE_TRENDLINE_TABLE_END -->"
ROUTING_BEGIN = "<!-- ROUTING_STALENESS_TABLE_BEGIN -->"
ROUTING_END = "<!-- ROUTING_STALENESS_TABLE_END -->"
ATTRIBUTION_BEGIN = "<!-- ATTRIBUTION_TABLE_BEGIN -->"
ATTRIBUTION_END = "<!-- ATTRIBUTION_TABLE_END -->"
BENCH_TREND_BEGIN = "<!-- BENCH_TREND_TABLE_BEGIN -->"
BENCH_TREND_END = "<!-- BENCH_TREND_TABLE_END -->"
AVAILABILITY_BEGIN = "<!-- AVAILABILITY_TABLE_BEGIN -->"
AVAILABILITY_END = "<!-- AVAILABILITY_TABLE_END -->"


def find_engine_throughput_json():
    """BENCH_engine_throughput.json from $BENCH_DIR, the repo root, else
    the checked-in baselines directory."""
    dirs = [
        os.environ.get("BENCH_DIR"),
        ROOT,
        os.path.join(ROOT, "benchmarks", "baselines"),
    ]
    for d in filter(None, dirs):
        p = os.path.join(d, "BENCH_engine_throughput.json")
        if os.path.exists(p):
            return p
    return None


def trendline_table(bench) -> str:
    """§Scale-out multi-device trendline from the engine_throughput rows."""
    rows = bench["metrics"].get("trendline", [])
    if not rows:
        return (
            "(no trendline rows in BENCH_engine_throughput.json — re-run "
            "`benchmarks/engine_throughput.py --trendline`)"
        )
    lines = [
        "| shards | sim-req/s | scaling vs 1 shard | routing on/off | peak live MiB/device | wall s |",
        "|---|---|---|---|---|---|",
    ]
    for r in rows:
        ratio = r.get("routing_on_off_ratio")
        lines.append(
            f"| {r['num_shards']} | {r['requests_per_s']:,.0f} | "
            f"{r['scaling_vs_1shard']:.2f}x | "
            f"{f'{ratio:.2f}x' if ratio is not None else '—'} | "
            f"{r['peak_live_bytes'] / 2**20:.1f} | {r['wall_s']:.2f} |"
        )
    lines.append("")
    r0 = rows[0]
    tail = (
        f"(`{r0['policy']}`, streamed trace, {r0['num_requests']:,} requests "
        f"/ {r0['num_keys']:,} keys, daemon_interval "
        f"{r0['daemon_interval']}, platform "
        f"{bench.get('backend_platform', '?')} — virtual host devices share "
        f"the physical cores, so CPU scaling tracks collective/program "
        f"overhead, not parallel speedup; real accelerators move the "
        f"curve.)"
    )
    scale = bench["metrics"].get("scale_acceptance")
    if scale:
        tail += (
            f"\n\nStreamed scale run: {scale['num_requests']:,} requests / "
            f"{scale['num_keys']:,} keys on ONE device in "
            f"{scale['wall_s']:.1f} s — peak live buffers "
            f"{scale['peak_live_bytes'] / 2**20:.1f} MiB vs "
            f"{scale['materialized_trace_bytes'] / 2**20:.1f} MiB for the "
            f"materialised path."
        )
    lines.append(tail)
    return "\n".join(lines)


def find_directory_staleness_json():
    """BENCH_directory_staleness.json from $BENCH_DIR, the repo root, else
    the checked-in baselines directory."""
    dirs = [
        os.environ.get("BENCH_DIR"),
        ROOT,
        os.path.join(ROOT, "benchmarks", "baselines"),
    ]
    for d in filter(None, dirs):
        p = os.path.join(d, "BENCH_directory_staleness.json")
        if os.path.exists(p):
            return p
    return None


def routing_staleness_table(bench) -> str:
    """§Routing-tier staleness frontier from the directory_staleness rows."""
    m = bench["metrics"]
    rows = m.get("lag_rows", [])
    if not rows:
        return (
            "(no lag rows in BENCH_directory_staleness.json — re-run "
            "`benchmarks/directory_staleness.py`)"
        )
    lines = [
        "| publish lag (chunks) | mean ms | P99 ms | read P99 ms | mis-routes | stale consults | beats best static |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['publish_lag_chunks']} | {r['mean_latency_ms']:.2f} | "
            f"{r['p99_ms']:.1f} | {r['p99_read_ms']:.1f} | "
            f"{r['mis_routes']:.0f} | {r['stale_consults']:.0f} | "
            f"{'yes' if r['beats_best_static'] else 'no'} |"
        )
    lines.append("")
    statics = m.get("static_rows", {})
    best = m.get("best_realizable_static", "?")
    static_txt = ", ".join(
        f"`static:{mode}` mean {row['mean_latency_ms']:.2f} / "
        f"P99 {row['p99_ms']:.1f}"
        for mode, row in statics.items()
    )
    win = m.get("max_winning_lag")
    lines.append(
        f"(redynis on diurnal wan5 — {bench['num_requests']:,} requests / "
        f"{bench['num_keys']:,} keys, daemon_interval "
        f"{bench['daemon_interval']}, read fraction "
        f"{bench['read_fraction']}; statics on the same trace: {static_txt}; "
        f"best realizable static by mean: `static:{best}`. Staleness "
        f"budget: redynis beats it on mean AND P99 through publish lag "
        f"{win if win is not None else '— none'}.)"
    )
    return "\n".join(lines)


def find_attribution_json():
    """BENCH_attribution.json from $BENCH_DIR, the repo root, else the
    checked-in baselines directory."""
    dirs = [
        os.environ.get("BENCH_DIR"),
        ROOT,
        os.path.join(ROOT, "benchmarks", "baselines"),
    ]
    for d in filter(None, dirs):
        p = os.path.join(d, "BENCH_attribution.json")
        if os.path.exists(p):
            return p
    return None


def attribution_table(bench) -> str:
    """§Observability per-policy component breakdown (wan5)."""
    m = bench["metrics"]
    components = m.get("components", {})
    rows = {r["policy"]: r for r in m.get("rows", [])}
    if not components:
        return (
            "(no component rows in BENCH_attribution.json — re-run "
            "`benchmarks/latency_attribution.py`)"
        )
    policies = list(components)
    comp_names = list(next(iter(components.values())))
    header = "| component | " + " | ".join(
        f"`{p}`" for p in policies
    ) + " |"
    lines = [header, "|---|" + "---|" * len(policies)]
    for name in comp_names:
        cells = []
        for p in policies:
            s = components[p][name]
            cells.append(f"{s['mean_ms']:.2f} ({100 * s['share']:.0f}%)")
        lines.append(f"| {name} | " + " | ".join(cells) + " |")
    totals = " | ".join(
        f"**{rows[p]['mean_latency_ms']:.2f}**" if p in rows else "—"
        for p in policies
    )
    lines.append(f"| **total mean ms** | {totals} |")
    lines.append("")
    ok = all(m.get("checks", {}).values()) if m.get("checks") else None
    lines.append(
        f"(per-request mean ms (share of total); wan5 + ServiceConfig + "
        f"RoutingConfig(publish_lag_chunks="
        f"{bench.get('routing_publish_lag_chunks', '?')}), "
        f"{bench['num_requests']:,} requests, read fraction "
        f"{bench['read_fraction']}; component-sum-reconstructs-total "
        f"checks: {'all pass' if ok else 'FAILING' if ok is not None else '?'}.)"
    )
    return "\n".join(lines)


def find_availability_json():
    """BENCH_availability.json from $BENCH_DIR, the repo root, else the
    checked-in baselines directory."""
    dirs = [
        os.environ.get("BENCH_DIR"),
        ROOT,
        os.path.join(ROOT, "benchmarks", "baselines"),
    ]
    for d in filter(None, dirs):
        p = os.path.join(d, "BENCH_availability.json")
        if os.path.exists(p):
            return p
    return None


def availability_table(bench) -> str:
    """§Failure-injection region-outage drill from the availability rows."""
    m = bench["metrics"]
    rows = m.get("rows", {})
    if not rows:
        return (
            "(no policy rows in BENCH_availability.json — re-run "
            "`benchmarks/availability.py`)"
        )
    lines = [
        "| policy | min avail | outage mean avail | outage P99 ms | unavail reads | failovers | repair moves | recovery (chunks) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for policy, r in rows.items():
        rec = r["recovery_chunks"]
        lines.append(
            f"| `{policy}` | {r['availability_min']:.3f} | "
            f"{r['availability_outage_mean']:.3f} | "
            f"{r['p99_outage_ms']:.1f} | {r['unavailable_reads']:.0f} | "
            f"{r['failovers']:.0f} | {r['repair_moves']:.0f} | "
            f"{rec if rec >= 0 else 'never'} |"
        )
    lines.append("")
    blast = m.get("blast_radius", [])
    if blast:
        lines += [
            "| failure | mode | window (chunks) | blast radius (unreachable) | blast radius (wiped) |",
            "|---|---|---|---|---|",
        ]
        for r in blast:
            lines.append(
                f"| {r['kind']} {r['target']} | {r['mode']} | "
                f"[{r['start_chunk']}, {r['end_chunk']}) | "
                f"{100 * r['blast_radius_unreachable']:.1f}% | "
                f"{100 * r['blast_radius_wiped']:.1f}% |"
            )
        lines.append("")
    o = m.get("outage", {})
    ok = all(m.get("checks", {}).values()) if m.get("checks") else None
    lines.append(
        f"(wan5 region-skewed trace, {bench['num_requests']:,} requests / "
        f"{bench['num_keys']:,} keys, read fraction "
        f"{bench['read_fraction']}; crash of {o.get('kind', '?')} "
        f"{o.get('target', '?')} over chunks [{o.get('start_chunk', '?')}, "
        f"{o.get('end_chunk', '?')}); recovery = chunks from outage start "
        f"until effective hit rate regains 95% of its pre-outage median; "
        f"acceptance checks: "
        f"{'all pass' if ok else 'FAILING' if ok is not None else '?'}.)"
    )
    return "\n".join(lines)


def bench_trend_table() -> str:
    """§Observability bench-trend dashboard (delegates to bench_trend.py,
    which walks the git history of benchmarks/baselines/BENCH_*.json)."""
    try:
        import bench_trend
    except ImportError:
        from benchmarks import bench_trend

    text, regressions = bench_trend.render_markdown(headline_only=True)
    if regressions:
        text += (
            f"\n**{regressions} gated metric(s) REGRESSED** — see "
            f"`python benchmarks/bench_trend.py --all-metrics`.\n"
        )
    return text.rstrip()


def tail_latency_table(bench) -> str:
    """§Telemetry quantile matrix from the tail_latency benchmark rows."""
    lines = [
        "| topology | policy | hit rate | P50 ms | P99 ms (±CI99) | P99.9 ms | conv. chunk | post-conv moves/seed |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in bench["metrics"]["rows"]:
        lines.append(
            f"| {r['topology']} | `{r['policy']}` | {r['hit_rate']:.3f} | "
            f"{r['p50_ms']:.1f} | {r['p99_ms']:.1f} (±{r['p99_ci99']:.1f}) | "
            f"{r['p999_ms']:.1f} | {r['convergence_chunk']} | "
            f"{r['post_convergence_moves_per_seed']:.0f} |"
        )
    lines.append("")
    lines.append(
        f"(from `BENCH_tail_latency.json`: {bench['num_requests']} requests × "
        f"{bench['iterations']} seeds, read fraction {bench['read_fraction']}, "
        f"{bench['num_bins']} bins)"
    )
    return "\n".join(lines)


def contention_table(bench) -> str:
    """§Queueing-model matrix from the contention-on grid rows."""
    c = bench["metrics"].get("contention", {})
    rows = c.get("rows", [])
    if not rows:
        return "(no contention rows in BENCH_tail_latency.json — re-run " \
               "`benchmarks/tail_latency.py` without `--no-contention`)"
    lines = [
        "| capacity_factor | policy | hit rate | peak ρ | P50 ms | P99 ms (±CI99) | P99.9 ms |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['capacity_factor']} | `{r['policy']}` | "
            f"{r['hit_rate']:.3f} | {r['peak_load_factor']:.3f} | "
            f"{r['p50_ms']:.1f} | {r['p99_ms']:.1f} (±{r['p99_ci99']:.1f}) | "
            f"{r['p999_ms']:.1f} |"
        )
    lines.append("")
    lines.append(
        f"(wan5 + `ServiceConfig(serve_bytes_per_ms="
        f"{c['serve_bytes_per_ms']:g})`, balanced region weights, read "
        f"fraction 1.0, lognormal object sizes σ={c['object_bytes_sigma']:g}; "
        f"{bench['num_requests']} requests × {bench['iterations']} seeds)"
    )
    return "\n".join(lines)


def main() -> None:
    res = all_results()
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(path) as f:
        doc = f.read()
    doc = doc.replace("<!-- DRYRUN_TABLE -->", dryrun_table(res))
    doc = doc.replace("<!-- ROOFLINE_TABLE -->", roofline_table(res))
    tail_json = find_tail_latency_json()
    if tail_json is not None:
        bench = load(tail_json)
        # The rendered tables live BETWEEN the markers (which stay in the
        # doc), so re-running this script refreshes them in place.
        for begin, end, render in (
            (TAIL_BEGIN, TAIL_END, tail_latency_table),
            (CONTENTION_BEGIN, CONTENTION_END, contention_table),
        ):
            if begin in doc and end in doc:
                doc = re.sub(
                    re.escape(begin) + r".*?" + re.escape(end),
                    f"{begin}\n{render(bench)}\n{end}",
                    doc,
                    flags=re.DOTALL,
                )
    engine_json = find_engine_throughput_json()
    if engine_json is not None and TRENDLINE_BEGIN in doc and TRENDLINE_END in doc:
        bench = load(engine_json)
        doc = re.sub(
            re.escape(TRENDLINE_BEGIN) + r".*?" + re.escape(TRENDLINE_END),
            f"{TRENDLINE_BEGIN}\n{trendline_table(bench)}\n{TRENDLINE_END}",
            doc,
            flags=re.DOTALL,
        )
    attr_json = find_attribution_json()
    if attr_json is not None and ATTRIBUTION_BEGIN in doc and ATTRIBUTION_END in doc:
        bench = load(attr_json)
        doc = re.sub(
            re.escape(ATTRIBUTION_BEGIN) + r".*?" + re.escape(ATTRIBUTION_END),
            f"{ATTRIBUTION_BEGIN}\n{attribution_table(bench)}\n"
            f"{ATTRIBUTION_END}",
            doc,
            flags=re.DOTALL,
        )
    if BENCH_TREND_BEGIN in doc and BENCH_TREND_END in doc:
        doc = re.sub(
            re.escape(BENCH_TREND_BEGIN) + r".*?" + re.escape(BENCH_TREND_END),
            f"{BENCH_TREND_BEGIN}\n{bench_trend_table()}\n{BENCH_TREND_END}",
            doc,
            flags=re.DOTALL,
        )
    avail_json = find_availability_json()
    if avail_json is not None and AVAILABILITY_BEGIN in doc and AVAILABILITY_END in doc:
        bench = load(avail_json)
        doc = re.sub(
            re.escape(AVAILABILITY_BEGIN) + r".*?" + re.escape(AVAILABILITY_END),
            f"{AVAILABILITY_BEGIN}\n{availability_table(bench)}\n"
            f"{AVAILABILITY_END}",
            doc,
            flags=re.DOTALL,
        )
    routing_json = find_directory_staleness_json()
    if routing_json is not None and ROUTING_BEGIN in doc and ROUTING_END in doc:
        bench = load(routing_json)
        doc = re.sub(
            re.escape(ROUTING_BEGIN) + r".*?" + re.escape(ROUTING_END),
            f"{ROUTING_BEGIN}\n{routing_staleness_table(bench)}\n"
            f"{ROUTING_END}",
            doc,
            flags=re.DOTALL,
        )
    with open(path, "w") as f:
        f.write(doc)
    print(f"EXPERIMENTS.md updated with {len(res)} cells")


if __name__ == "__main__":
    main()
