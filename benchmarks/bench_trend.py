"""Bench-trend dashboard: the perf trajectory of the checked-in baselines.

Every benchmark persists a ``BENCH_<name>.json`` (``common.write_bench_json``
stamps ``schema_version`` + ``git_commit``), and the blessed copies live in
``benchmarks/baselines/`` — one file per benchmark, *rewritten in place* as
PRs land. The trajectory is therefore the git history of those files: this
tool walks ``git log`` per baseline, loads every committed revision (plus
the working-tree copy when it differs), flattens each payload into dotted
scalar metrics, and renders a per-metric trend table — first / previous /
latest / Δ% — with regression flags. Each trajectory point is annotated
with its blessing commit's subject line (``git log --format=%s``), so the
dashboard reads as "which PR moved this metric".

Regression gating is deliberately narrow: only *machine-independent* gated
metrics are flagged (the ``checks.*`` booleans every benchmark emits, and
counters declared in ``GATES``), because committed wall-times and
throughputs come from whatever machine ran the blessing run. Timing columns
still trend in the table; they just never fail CI.

Usage::

    python benchmarks/bench_trend.py                     # print trend tables
    python benchmarks/bench_trend.py --fail-on-regression  # CI gate (exit 1)
    python benchmarks/bench_trend.py --json trend.json   # machine-readable

``make_report.py`` imports :func:`render_markdown` to refresh the
``BENCH_TREND_TABLE`` block in EXPERIMENTS.md.
"""

from __future__ import annotations

import argparse
import fnmatch
import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE_DIR = os.path.join("benchmarks", "baselines")

# (bench glob, dotted-metric glob, mode) — the machine-independent gates.
#   "truthy":       flag when the metric goes truthy -> falsy
#   "non_increase": flag when the metric increases between the last two points
GATES = [
    ("*", "checks.*", "truthy"),
    ("*", "*.passed", "truthy"),
    ("*", "regressions", "non_increase"),
]

# Per-bench dotted-prefix allowlist for the EXPERIMENTS.md table (the CLI
# always prints everything). Unknown benches fall back to all metrics.
HEADLINE_PREFIXES = {
    "engine_throughput": (
        "checks.", "regressions", "wall_time_s",
        "rows.mean.requests_per_s", "speedups.mean.",
        "scale_acceptance.requests_per_s", "scale_acceptance.passed",
    ),
    "directory_staleness": (
        "checks.", "best_static_mean_ms", "best_static_p99_ms",
        "max_winning_lag", "wall_time_s", "lag_rows.mean.mean_latency_ms",
        "lag_rows.mean.p99_ms",
    ),
    "tail_latency": (
        "wall_time_s", "rows.mean.mean_latency_ms", "rows.mean.p999_ms",
        "rows.mean.p50_ms",
    ),
    "attribution": ("checks.", "rows.mean.", "wall_time_s"),
    "availability": (
        "checks.", "wall_time_s", "rows.redynis.availability_min",
        "rows.redynis.p99_outage_ms", "rows.redynis.recovery_chunks",
        "rows.redynis.repair_moves", "rows.static:replicated.recovery_chunks",
        "blast_radius.mean.",
    ),
}


def flatten_metrics(payload: dict) -> dict:
    """``BENCH_*.json`` payload -> flat ``{dotted.path: float}``.

    Dicts nest with ``.``; numeric scalars (bools become 0/1) are kept;
    strings are dropped. Lists of dicts — the per-config row tables — are
    summarised instead of exploded: each numeric field contributes its MEAN
    under ``<list>.mean.<field>`` plus a ``<list>.len`` count, so a trend
    over "the rows got slower on average" survives without 200 columns.
    """
    out: dict = {}

    def walk(prefix: str, val) -> None:
        if isinstance(val, bool):
            out[prefix] = float(val)
        elif isinstance(val, (int, float)):
            out[prefix] = float(val)
        elif isinstance(val, dict):
            for k, v in sorted(val.items()):
                walk(f"{prefix}.{k}" if prefix else str(k), v)
        elif isinstance(val, list) and val and all(
            isinstance(e, dict) for e in val
        ):
            out[f"{prefix}.len"] = float(len(val))
            fields: dict = {}
            for e in val:
                for k, v in e.items():
                    if isinstance(v, bool):
                        v = float(v)
                    if isinstance(v, (int, float)):
                        fields.setdefault(k, []).append(float(v))
            for k, vs in sorted(fields.items()):
                out[f"{prefix}.mean.{k}"] = sum(vs) / len(vs)

    walk("", payload.get("metrics", {}))
    return out


def _git(*args: str) -> str:
    return subprocess.run(
        ["git", *args], cwd=ROOT, capture_output=True, text=True, check=True
    ).stdout


def baseline_files() -> list[str]:
    """Repo-relative paths of the checked-in baseline BENCH files."""
    d = os.path.join(ROOT, BASELINE_DIR)
    if not os.path.isdir(d):
        return []
    return sorted(
        os.path.join(BASELINE_DIR, f)
        for f in os.listdir(d)
        if f.startswith("BENCH_") and f.endswith(".json")
    )


def collect_trajectory(relpath: str) -> list[dict]:
    """All committed revisions of one baseline file (oldest first), plus a
    trailing ``worktree`` point when the file on disk differs from HEAD's
    copy. Each point: ``{"rev", "subject", "bench", "schema_version",
    "git_commit", "unix_time", "metrics": {dotted: float}}`` — ``subject``
    is the blessing commit's one-line message, so trajectory points read as
    the PRs that moved them. Unparseable revisions are skipped."""
    try:
        lines = _git(
            "log", "--reverse", "--format=%H%x09%s", "--", relpath
        ).splitlines()
    except subprocess.CalledProcessError:
        lines = []
    points = []
    last_blob = None
    for line in lines:
        rev, _, subject = line.partition("\t")
        if not rev:
            continue
        try:
            blob = _git("show", f"{rev}:{relpath}")
            payload = json.loads(blob)
        except (subprocess.CalledProcessError, json.JSONDecodeError):
            continue
        last_blob = blob
        points.append(_point(rev[:10], payload, subject))
    disk = os.path.join(ROOT, relpath)
    if os.path.exists(disk):
        with open(disk) as fh:
            blob = fh.read()
        if blob != last_blob:
            try:
                points.append(
                    _point("worktree", json.loads(blob), "(uncommitted)")
                )
            except json.JSONDecodeError:
                pass
    return points


def _point(rev: str, payload: dict, subject: str = "") -> dict:
    return {
        "rev": rev,
        "subject": subject,
        "bench": payload.get("bench", "?"),
        "schema_version": payload.get("schema_version"),
        "git_commit": (payload.get("git_commit") or "")[:10] or None,
        "unix_time": payload.get("unix_time"),
        "metrics": flatten_metrics(payload),
    }


def _gate_mode(bench: str, metric: str) -> str | None:
    for bench_pat, metric_pat, mode in GATES:
        if fnmatch.fnmatch(bench, bench_pat) and fnmatch.fnmatch(
            metric, metric_pat
        ):
            return mode
    return None


def trend_rows(points: list[dict]) -> list[dict]:
    """Per-metric trend over a trajectory: first / prev / last / Δ% (last
    vs prev, ``None`` when prev is 0 or missing) / regression flag."""
    if not points:
        return []
    bench = points[-1]["bench"]
    metrics = sorted(points[-1]["metrics"])
    rows = []
    for m in metrics:
        series = [p["metrics"].get(m) for p in points]
        present = [v for v in series if v is not None]
        last = series[-1]
        prev = next(
            (v for v in reversed(series[:-1]) if v is not None), None
        )
        first = present[0]
        delta = (
            100.0 * (last - prev) / abs(prev)
            if prev not in (None, 0.0) and last is not None
            else None
        )
        mode = _gate_mode(bench, m)
        regressed = False
        if mode == "truthy" and last is not None:
            regressed = bool(prev) and not bool(last)
        elif mode == "non_increase" and last is not None and prev is not None:
            regressed = last > prev
        rows.append(
            {
                "metric": m,
                "first": first,
                "prev": prev,
                "last": last,
                "delta_pct": delta,
                "gated": mode is not None,
                "regressed": regressed,
            }
        )
    return rows


def _truncate(s: str, width: int = 72) -> str:
    return s if len(s) <= width else s[: width - 1] + "…"


def _fmt(v) -> str:
    if v is None:
        return "—"
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return f"{v:.4g}"


def _table(rows: list[dict], points: list[dict]) -> list[str]:
    n = len(points)
    span = f"{points[0]['rev']} → {points[-1]['rev']}"
    lines = [f"{n} point{'s' if n != 1 else ''} ({span})"]
    for p in points:
        subject = p.get("subject") or ""
        if subject:
            lines.append(f"- `{p['rev']}` — {_truncate(subject)}")
    lines += [
        "",
        "| metric | first | prev | latest | Δ% | flag |",
        "|---|---:|---:|---:|---:|---|",
    ]
    for r in rows:
        if r["regressed"]:
            flag = "**REGRESSED**"
        elif r["gated"]:
            flag = "gated ✓"
        else:
            flag = ""
        delta = "—" if r["delta_pct"] is None else f"{r['delta_pct']:+.1f}%"
        lines.append(
            f"| `{r['metric']}` | {_fmt(r['first'])} | {_fmt(r['prev'])} "
            f"| {_fmt(r['last'])} | {delta} | {flag} |"
        )
    return lines


def render_markdown(headline_only: bool = True) -> tuple[str, int]:
    """The full dashboard as markdown. Returns ``(text, num_regressions)``."""
    out: list[str] = []
    regressions = 0
    for rel in baseline_files():
        points = collect_trajectory(rel)
        if not points:
            continue
        bench = points[-1]["bench"]
        rows = trend_rows(points)
        regressions += sum(r["regressed"] for r in rows)
        if headline_only:
            prefixes = HEADLINE_PREFIXES.get(bench)
            if prefixes:
                rows = [
                    r
                    for r in rows
                    if r["regressed"]
                    or any(r["metric"].startswith(p) for p in prefixes)
                ]
        out.append(f"**{bench}** — `{rel}`")
        out.extend(_table(rows, points))
        out.append("")
    if not out:
        out = ["(no committed BENCH baselines found)"]
    return "\n".join(out).rstrip() + "\n", regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument(
        "--fail-on-regression",
        action="store_true",
        help="exit 1 when any gated metric regressed between the last two "
        "trajectory points",
    )
    ap.add_argument(
        "--json", metavar="PATH", help="also write the trajectory as JSON"
    )
    ap.add_argument(
        "--all-metrics",
        action="store_true",
        help="print every flattened metric, not just the headline set",
    )
    args = ap.parse_args(argv)

    text, regressions = render_markdown(headline_only=not args.all_metrics)
    print(text)
    if args.json:
        doc = {
            rel: collect_trajectory(rel) for rel in baseline_files()
        }
        with open(args.json, "w") as fh:
            json.dump(doc, fh, indent=2)
        print(f"WROTE,{args.json}")
    if regressions:
        print(f"REGRESSIONS,{regressions}")
    if args.fail_on_regression and regressions:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
