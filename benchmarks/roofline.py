"""Aggregate the dry-run sweep (benchmarks/results/dryrun/*.json) into the
§Roofline table: per (arch × shape × mesh) the three terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS, and per-device memory."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import banner, emit

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load_all() -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        try:
            with open(path) as f:
                out.append(json.load(f))
        except (json.JSONDecodeError, OSError):
            continue
    return out


def fmt_row(r: dict) -> str:
    if r.get("skipped"):
        return f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} SKIP ({r['skipped'][:40]}...)"
    if not r.get("ok"):
        return f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} FAILED {r.get('error', '')[:60]}"
    t = r["roofline"]
    mem = r["memory"]["peak_bytes_per_device"] / 1e9
    return (
        f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} "
        f"c={t['compute_s']:9.4f}s m={t['memory_s']:9.4f}s x={t['collective_s']:9.4f}s "
        f"dom={t['dominant']:10s} useful={t.get('useful_flops_frac', 0):5.2f} "
        f"roofline={t.get('roofline_frac', 0):7.4f} mem={mem:5.1f}GB"
    )


def main() -> None:
    banner("roofline: (arch x shape x mesh) from the dry-run sweep")
    rows = load_all()
    if not rows:
        print("no dry-run results yet — run benchmarks/dryrun_sweep.sh")
        return
    for r in rows:
        print(fmt_row(r))
        if r.get("ok") and not r.get("skipped"):
            t = r["roofline"]
            emit(
                "roofline",
                round(t.get("roofline_frac", 0.0), 5),
                "frac",
                arch=r["arch"],
                shape=r["shape"],
                mesh=r["mesh"],
                dominant=t["dominant"],
                compute_s=round(t["compute_s"], 5),
                memory_s=round(t["memory_s"], 5),
                collective_s=round(t["collective_s"], 5),
                fits=r["memory"]["fits_16GB"],
            )
    ok = [r for r in rows if r.get("ok") and not r.get("skipped")]
    fail = [r for r in rows if not r.get("ok")]
    skip = [r for r in rows if r.get("skipped")]
    print(f"\n{len(ok)} ok / {len(skip)} skipped / {len(fail)} failed of {len(rows)} cells")


if __name__ == "__main__":
    main()
