"""Hit-rate / throughput vs per-node replica budget — the scenario axis the
capacity projection opens (paper Algorithm 3 never models memory pressure;
size-aware sharding and DINOMO's elastic capacity management both show this
is where placement gets interesting).

Sweeps the OPTIMIZED scenario across shrinking ``capacity_bytes`` (inf =
the paper, then budgets above / around / well below the hot set, which is
hot_fraction × num_keys × object_bytes ≈ 100 KiB at the defaults) on the
skewed workload with a lognormal object-size distribution, and persists
``BENCH_capacity_sweep.json``."""

from __future__ import annotations

import argparse
import time

from benchmarks.common import banner, emit, write_bench_json
from repro.kvsim import (
    ClusterConfig,
    RedynisPolicy,
    WorkloadConfig,
    describe_policy,
    parse_policy,
    run_scenario,
)

DEFAULT_CAPACITIES_KIB = (float("inf"), 256, 128, 64, 32, 16)


def main(
    num_requests: int = 50_000,
    capacities_kib=DEFAULT_CAPACITIES_KIB,
    object_bytes_sigma: float = 0.5,
    backend: str = "jax",
    seed: int = 0,
    policy=None,
) -> list[dict]:
    if policy is None:
        policy = RedynisPolicy(backend=backend)
    banner(
        f"capacity_sweep: hit-rate vs per-node replica budget "
        f"(policy={describe_policy(policy)})"
    )
    wl = WorkloadConfig(
        num_requests=num_requests,
        skewed=True,
        object_bytes_sigma=object_bytes_sigma,
    )
    rows: list[dict] = []
    t_start = time.perf_counter()
    for cap_kib in capacities_kib:
        cap = float("inf") if cap_kib == float("inf") else cap_kib * 1024.0
        cl = ClusterConfig(capacity_bytes=cap)
        t0 = time.perf_counter()
        r = run_scenario(wl, cl, policy, seed=seed)
        wall = time.perf_counter() - t0
        label = "inf" if cap == float("inf") else f"{cap_kib:g}"
        emit(
            "capacity_sweep",
            round(r.throughput_ops_s, 2),
            "ops/s",
            capacity_kib=label,
            hit_rate=round(r.hit_rate, 4),
            capacity_evictions=int(r.capacity_evictions),
            repl_moves=int(r.replication_moves),
            peak_occupancy_kib=round(float(r.peak_occupancy_bytes.max()) / 1024.0, 1),
        )
        rows.append(
            {
                "capacity_kib": None if cap == float("inf") else cap_kib,
                "throughput_ops_s": r.throughput_ops_s,
                "hit_rate": r.hit_rate,
                "mean_latency_ms": r.mean_latency_ms,
                "replication_moves": r.replication_moves,
                "capacity_evictions": r.capacity_evictions,
                "evictions": r.evictions,
                "peak_occupancy_bytes": r.peak_occupancy_bytes.tolist(),
                "wall_time_s": wall,
            }
        )
    write_bench_json(
        "capacity_sweep",
        {"rows": rows, "wall_time_s": time.perf_counter() - t_start},
        policy=describe_policy(policy),
        num_requests=num_requests,
        object_bytes_sigma=object_bytes_sigma,
    )
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--num-requests", type=int, default=50_000)
    ap.add_argument("--backend", choices=("jax", "pallas"), default="jax")
    ap.add_argument(
        "--policy", type=parse_policy, default=None, metavar="NAME[:k=v,...]",
        help="placement policy spec, e.g. redynis:h=0.2 or topk:k=50 "
        "(default: redynis with --backend)",
    )
    ap.add_argument(
        "--capacities-kib", type=float, nargs="+", default=None,
        help="per-node budgets in KiB (omit for the default ladder incl. inf)",
    )
    args = ap.parse_args()
    caps = (
        tuple(args.capacities_kib)
        if args.capacities_kib
        else DEFAULT_CAPACITIES_KIB
    )
    main(
        num_requests=args.num_requests,
        capacities_kib=caps,
        backend=args.backend,
        policy=args.policy,
    )
