"""Beyond-paper: traffic-aware expert placement on a reduced MoE.

Trains the reduced deepseek-moe config twice — replica cache OFF (pure
all-to-all) vs ON (Redynis daemon managing R hot slots per layer) — and
reports: replica-cache hit rate over training, token-drop rates, and the
analytic all-to-all bytes per step each configuration implies at the
production shard sizes (the serving-side numbers the dry-run corroborates).
"""

from __future__ import annotations

import dataclasses

import jax

from benchmarks.common import banner, emit
from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig, Pipeline
from repro.models import build
from repro.models.moe import cold_capacity
from repro.train.optim import OptConfig
from repro.train.trainer import TrainConfig, Trainer


def a2a_bytes_per_layer(cfg, tokens_per_group: int, groups: int) -> float:
    """Dispatch + combine payload of the cold path: 2 × [E, C, D] buffers."""
    c = cold_capacity(cfg, tokens_per_group)
    return 2.0 * groups * cfg.num_experts * c * cfg.d_model * 2  # bf16


def main(steps: int = 40) -> None:
    banner("moe_placement: hot-expert replica cache (Redynis integration #1)")
    base = dataclasses.replace(
        reduced(get_config("deepseek-moe-16b")), sweep_period=5
    )
    pipe_cfg = DataConfig(vocab_size=base.vocab_size, seq_len=64, global_batch=8, zipf_a=1.3)

    for label, cfg in (
        ("baseline_a2a", dataclasses.replace(base, hot_expert_slots=0)),
        ("redynis_hot", base),
    ):
        model = build(cfg)
        tr = Trainer(
            model,
            TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=5, total_steps=steps), log_every=1000),
            num_nodes=4,
        )
        st = tr.init_state(jax.random.PRNGKey(0))
        st, hist = tr.run(st, Pipeline(pipe_cfg), steps, log=False)
        hot = [h.get("moe_hot_frac", 0.0) for h in hist]
        drop = [h.get("moe_dropped", 0.0) for h in hist]
        emit(
            "moe_placement",
            round(hist[-1]["loss"], 4),
            "final_loss",
            mode=label,
            hot_frac_last10=round(sum(hot[-10:]) / 10, 3),
            dropped_last10=round(sum(drop[-10:]) / 10, 3),
        )
        bytes_l = a2a_bytes_per_layer(cfg, tokens_per_group=512, groups=2048)
        emit(
            "moe_a2a_bytes_per_layer",
            round(bytes_l / 1e6, 1),
            "MB@prod-shapes",
            mode=label,
        )
        if label == "redynis_hot":
            hr = float(tr.expert_daemon.hit_rate(st.expert_placement))
            emit("moe_replica_hit_rate", round(hr, 3), "frac", mode=label)


if __name__ == "__main__":
    main()
