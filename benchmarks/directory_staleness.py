"""Directory-staleness frontier: redynis P99 vs ``publish_lag_chunks``.

The experiment the routing tier (ISSUE 8) exists for: a real deployment
never reads the daemon's ownership map synchronously — router sites hold a
cached view that lags the placement decisions by a publish interval, and
every chunk of lag converts some fraction of directory consults into
mis-routed detours. This sweep prices that staleness axis end to end on
the diurnal wan5 scenario (a rotating hot region, so placement genuinely
moves and a lagged directory genuinely mis-routes — a *static* hotset
yields zero staleness because the daemon only moves keys whose readers
already left):

  * **lag ladder** — redynis under ``RoutingConfig(publish_lag_chunks=L)``
    for each L in the sweep: mean/P50/P99/P99.9 latency off the in-scan
    telemetry histograms (overall AND read-split), plus the routing
    counters (consults, directory fetches, stale consults, mis-routes,
    peak per-chunk mis-route rate).
  * **static frontier** — the realizable static placements (``remote``,
    ``replicated``) on the same trace with the routing tier off. A static
    map never changes, so no lag can stale it; these are the lag-free
    alternatives a deployment would fall back to. The *best* static is
    chosen by mean latency — the metric a deployment would pick its
    placement policy on. (``static:local`` is the idealised
    everything-local bound — unbeatable by construction, reported in the
    JSON for scale but excluded from the "best static" frontier.)
  * **acceptance checks** — the ISSUE-8 criteria, recorded in the JSON and
    promoted to a hard exit by ``--fail-on-regression``:
      1. routing-off bit-exactness: ``routing=None`` and
         ``RoutingConfig(enabled=False)`` produce identical ``SimResult``s
         and telemetry leaves (the off-path is structurally the PR-7
         program);
      2. monotone degradation: redynis P99 never improves as
         ``publish_lag_chunks`` grows — overall and read-split, plus the
         mean and the mis-route count. At the default 30%-write mix the
         *overall* P99 is capped by the replication-write broadcast tail
         (every lag lands in the same histogram bin), so the strict
         staleness signal is the **read** P99: directory consults happen
         only on the read path, and every added chunk of lag detours more
         reads through a stale owner;
      3. finite crossover: some measured lag exists at which redynis still
         beats the best realizable static on BOTH mean latency and
         overall P99 — the staleness budget the routing tier buys before
         a lag-free static placement would serve the same traffic better.

Persists ``BENCH_directory_staleness.json`` (rows + quantiles blocks +
check verdicts). The checked-in baseline records the full ladder
(0..128); CI smoke runs a 3-point subset via ``--lags 0 8 64`` with a
smaller trace.
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.common import banner, emit, write_bench_json
from repro.kvsim import (
    RoutingConfig,
    StaticPolicy,
    RedynisPolicy,
    TelemetryConfig,
    diurnal_workload,
    run_scenario,
    wan5_cluster,
)

DEFAULT_LAGS = (0, 1, 2, 4, 8, 16, 32, 64, 128)
STATIC_MODES = ("remote", "replicated", "local")
REALIZABLE_STATICS = ("remote", "replicated")


def _run(wl, cluster, policy, *, daemon_interval, seed, replay_backend,
         num_bins):
    return run_scenario(
        wl,
        cluster,
        policy,
        seed=seed,
        daemon_interval=daemon_interval,
        telemetry=TelemetryConfig(num_bins=num_bins),
        replay_backend=replay_backend,
    )


def _row(result, trace) -> dict:
    q = trace.tail_summary()
    return {
        "mean_latency_ms": float(result.mean_latency_ms),
        "p50_ms": q["p50"],
        "p99_ms": q["p99"],
        "p999_ms": q["p999"],
        "p99_read_ms": trace.quantile(0.99, split="read"),
        "hit_rate": float(result.hit_rate),
        "throughput_ops_s": float(result.throughput_ops_s),
        "router_consults": float(result.router_consults),
        "directory_fetches": float(result.directory_fetches),
        "stale_consults": float(result.stale_consults),
        "mis_routes": float(result.mis_routes),
        "peak_mis_route_rate": float(trace.mis_route_rate.max()),
    }


def _check_routing_off_bitexact(wl, cluster, *, daemon_interval, seed,
                                replay_backend, num_bins) -> bool:
    """``RoutingConfig(enabled=False)`` must be *the same program* as
    ``routing=None`` — bit-exact SimResult fields and telemetry arrays."""
    r_none, t_none = _run(
        wl, cluster, RedynisPolicy(), daemon_interval=daemon_interval,
        seed=seed, replay_backend=replay_backend, num_bins=num_bins,
    )
    r_off, t_off = _run(
        wl, cluster._replace(routing=RoutingConfig(enabled=False)),
        RedynisPolicy(), daemon_interval=daemon_interval, seed=seed,
        replay_backend=replay_backend, num_bins=num_bins,
    )
    ok = True
    for name in r_none._fields:
        a, b = getattr(r_none, name), getattr(r_off, name)
        if not np.array_equal(np.asarray(a), np.asarray(b)):
            print(f"BITEXACT_MISMATCH,SimResult.{name},{a!r},{b!r}")
            ok = False
    for name in ("hist_group", "hit_rate", "mean_latency_ms", "moves",
                 "occupancy_bytes", "load_factor"):
        if not np.array_equal(getattr(t_none, name), getattr(t_off, name)):
            print(f"BITEXACT_MISMATCH,SimTrace.{name}")
            ok = False
    return ok


def _monotone(values, rel_tol: float = 1e-6) -> bool:
    """Non-decreasing up to a relative tolerance (histogram quantiles can
    tie bit-for-bit across adjacent lags)."""
    v = np.asarray(values, dtype=np.float64)
    return bool(np.all(np.diff(v) >= -rel_tol * np.maximum(v[:-1], 1.0)))


def main(
    num_requests: int = 100_000,
    num_keys: int = 1_000,
    lags=DEFAULT_LAGS,
    daemon_interval: int = 200,
    affinity: float = 0.8,
    read_fraction: float = 0.7,
    cache_entries: int = 0,
    seed: int = 0,
    num_bins: int = 128,
    replay_backend: str = "jax",
    fail_on_regression: bool = False,
) -> dict:
    banner(
        "directory_staleness: redynis P99 vs publish lag, diurnal wan5 "
        f"({num_requests:,} requests / {num_keys:,} keys, "
        f"daemon_interval={daemon_interval})"
    )
    wl = diurnal_workload(
        num_requests=num_requests,
        num_keys=num_keys,
        affinity=affinity,
        read_fraction=read_fraction,
    )
    cluster = wan5_cluster()
    t_start = time.perf_counter()

    checks = {}
    checks["routing_off_bitexact"] = _check_routing_off_bitexact(
        wl, cluster, daemon_interval=daemon_interval, seed=seed,
        replay_backend=replay_backend, num_bins=num_bins,
    )

    static_rows, quantiles = {}, {}
    for mode in STATIC_MODES:
        res, trace = _run(
            wl, cluster, StaticPolicy(mode=mode),
            daemon_interval=daemon_interval, seed=seed,
            replay_backend=replay_backend, num_bins=num_bins,
        )
        static_rows[mode] = _row(res, trace)
        quantiles[f"static:{mode}"] = trace.tail_summary()
        emit(
            "directory_staleness_static",
            round(static_rows[mode]["p99_ms"], 2),
            "p99_ms",
            policy=f"static:{mode}",
            mean=round(static_rows[mode]["mean_latency_ms"], 4),
            realizable=int(mode in REALIZABLE_STATICS),
        )
    best_static = min(REALIZABLE_STATICS,
                      key=lambda m: static_rows[m]["mean_latency_ms"])
    best_static_mean = static_rows[best_static]["mean_latency_ms"]
    best_static_p99 = static_rows[best_static]["p99_ms"]

    lag_rows = []
    for lag in lags:
        routing = RoutingConfig(
            publish_lag_chunks=lag, cache_entries=cache_entries,
        )
        res, trace = _run(
            wl, cluster._replace(routing=routing), RedynisPolicy(),
            daemon_interval=daemon_interval, seed=seed,
            replay_backend=replay_backend, num_bins=num_bins,
        )
        row = {"publish_lag_chunks": lag, **_row(res, trace)}
        row["beats_best_static"] = bool(
            row["mean_latency_ms"] < best_static_mean
            and row["p99_ms"] < best_static_p99
        )
        lag_rows.append(row)
        quantiles[f"redynis/lag{lag}"] = trace.tail_summary()
        emit(
            "directory_staleness",
            round(row["p99_ms"], 2),
            "p99_ms",
            publish_lag_chunks=lag,
            p99_read=round(row["p99_read_ms"], 2),
            mean=round(row["mean_latency_ms"], 4),
            mis_routes=int(row["mis_routes"]),
            stale_consults=int(row["stale_consults"]),
            directory_fetches=int(row["directory_fetches"]),
            beats_best_static=int(row["beats_best_static"]),
        )

    checks["p99_monotone_in_lag"] = _monotone(
        [r["p99_ms"] for r in lag_rows]
    )
    checks["p99_read_monotone_in_lag"] = _monotone(
        [r["p99_read_ms"] for r in lag_rows]
    )
    checks["mean_monotone_in_lag"] = _monotone(
        [r["mean_latency_ms"] for r in lag_rows]
    )
    checks["mis_routes_monotone_in_lag"] = _monotone(
        [r["mis_routes"] for r in lag_rows]
    )
    winning = [r["publish_lag_chunks"] for r in lag_rows
               if r["beats_best_static"]]
    checks["finite_crossover_lag_exists"] = bool(winning)
    emit(
        "directory_staleness_checks",
        int(all(checks.values())),
        "all_ok",
        best_static=best_static,
        best_static_mean=round(best_static_mean, 4),
        best_static_p99=round(best_static_p99, 2),
        max_winning_lag=max(winning) if winning else -1,
        **{k: int(v) for k, v in checks.items()},
    )

    write_bench_json(
        "directory_staleness",
        {
            "lag_rows": lag_rows,
            "static_rows": static_rows,
            "best_realizable_static": best_static,
            "best_static_mean_ms": best_static_mean,
            "best_static_p99_ms": best_static_p99,
            "max_winning_lag": max(winning) if winning else None,
            "checks": checks,
            "wall_time_s": time.perf_counter() - t_start,
        },
        quantiles=quantiles,
        num_requests=num_requests,
        num_keys=num_keys,
        daemon_interval=daemon_interval,
        affinity=affinity,
        read_fraction=read_fraction,
        cache_entries=cache_entries,
        seed=seed,
        num_bins=num_bins,
        lags=list(lags),
        replay_backend=replay_backend,
    )
    if fail_on_regression and not all(checks.values()):
        failed = [k for k, v in checks.items() if not v]
        print(f"FAIL,directory_staleness,checks_failed={';'.join(failed)}")
        sys.exit(1)
    return {"lag_rows": lag_rows, "static_rows": static_rows,
            "checks": checks}


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--num-requests", type=int, default=100_000)
    ap.add_argument("--num-keys", type=int, default=1_000)
    ap.add_argument(
        "--lags", nargs="+", type=int, default=list(DEFAULT_LAGS),
        help="publish_lag_chunks ladder (ascending)",
    )
    ap.add_argument("--daemon-interval", type=int, default=200)
    ap.add_argument("--affinity", type=float, default=0.8)
    ap.add_argument("--read-fraction", type=float, default=0.7)
    ap.add_argument(
        "--cache-entries", type=int, default=0,
        help="per-router cache capacity (0 = unbounded)",
    )
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--num-bins", type=int, default=128)
    ap.add_argument(
        "--replay-backend", choices=["jax", "pallas"], default="jax",
    )
    ap.add_argument(
        "--fail-on-regression", action="store_true",
        help="exit nonzero when any acceptance check fails (routing-off "
        "bit-exactness, P99/mean/mis-route monotonicity, finite crossover)",
    )
    args = ap.parse_args()
    main(
        num_requests=args.num_requests,
        num_keys=args.num_keys,
        lags=tuple(sorted(args.lags)),
        daemon_interval=args.daemon_interval,
        affinity=args.affinity,
        read_fraction=args.read_fraction,
        cache_entries=args.cache_entries,
        seed=args.seed,
        num_bins=args.num_bins,
        replay_backend=args.replay_backend,
        fail_on_regression=args.fail_on_regression,
    )
