"""Beyond-paper: hot-row embedding cache (Redynis integration #2).

Sweeps the ownership coefficient / cache size against zipfian token traffic
and reports: cache hit rate, analytic HBM bytes saved per training step at
production shapes (hits × d_model × dtype — rows served from VMEM instead
of HBM), and the lookup correctness/latency through the hot_gather kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import banner, emit, time_fn
from repro.core.hot_embedding import HotEmbedding, embed_with_cache


def main() -> None:
    banner("hot_embedding: hot-row cache hit rate vs cache size")
    vocab, d = 32_000, 2048
    rng = np.random.default_rng(0)
    ranks = np.arange(1, vocab + 1) ** -1.1
    probs = ranks / ranks.sum()

    for rows in (512, 2048, 8192):
        he = HotEmbedding(vocab=vocab, num_nodes=16, rows=rows, period=2)
        hs = he.init_state()
        for step in range(6):
            toks = rng.choice(vocab, (16, 512), p=probs)
            hs = he.fold(hs, jnp.asarray(toks, jnp.int32), jnp.arange(16, dtype=jnp.int32))
            if he.due(step + 1):
                hs = he.sweep(hs)
        # measured hit rate on a fresh batch
        toks = jnp.asarray(rng.choice(vocab, (4, 512), p=probs), jnp.int32)
        table = jnp.zeros((vocab, 64), jnp.bfloat16)  # d=64 for CPU speed
        rows_out, hit = embed_with_cache(table, toks, hs, use_kernel=False)
        hit_rate = float(hit.mean())
        # production shapes: train_4k tokens/step/chip = 4096*256/256 = 4096
        tokens_per_chip = 4096
        saved = hit_rate * tokens_per_chip * d * 2
        emit(
            "hot_embedding",
            round(hit_rate, 4),
            "hit_rate",
            rows=rows,
            hbm_saved_per_step_chip_MB=round(saved / 1e6, 2),
            traffic_frac=round(float(he.hit_rate(hs)), 4),
        )

    banner("hot_embedding: two-level lookup wall time (CPU, jnp fallback)")
    he = HotEmbedding(vocab=vocab, num_nodes=1, rows=2048, period=1)
    hs = he.init_state()
    toks0 = jnp.asarray(rng.choice(vocab, (16, 512), p=probs), jnp.int32)
    hs = he.fold(hs, toks0, jnp.zeros((16,), jnp.int32))
    hs = he.sweep(hs)
    table = jax.random.normal(jax.random.PRNGKey(0), (vocab, 256)).astype(jnp.bfloat16)
    toks = jnp.asarray(rng.choice(vocab, (4, 512), p=probs), jnp.int32)

    f_plain = jax.jit(lambda t, tok: jnp.take(t, tok, axis=0))
    f_cache = jax.jit(lambda t, tok, s: embed_with_cache(t, tok, s, use_kernel=False)[0])
    t_plain = time_fn(f_plain, table, toks, iters=20)
    t_cache = time_fn(f_cache, table, toks, hs, iters=20)
    emit("hot_embedding_lookup_us", round(t_plain * 1e6, 1), "us", mode="plain_take")
    emit("hot_embedding_lookup_us", round(t_cache * 1e6, 1), "us", mode="two_level")


if __name__ == "__main__":
    main()
