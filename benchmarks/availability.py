"""Region-outage drill: availability, degraded-window P99, re-convergence.

The experiment the failure-injection subsystem (ISSUE 10) exists for: crash
the hottest wan5 region mid-trace, recover it later, and price what each
placement policy actually delivers while the cluster is degraded:

  * **policy drill** — redynis vs the realizable statics (``replicated``,
    ``remote``) under the same ``region_outage`` schedule: per-chunk
    availability (served / attempted) min + outage-window mean, the P99 over
    the outage window only (summed ``chunk_hist`` rows → interpolated
    quantile), unavailable read/write counts, write failovers, daemon
    repair moves, and ``recovery_chunks`` — chunks from outage start until
    the effective hit rate (unavailable reads count as misses) first
    returns to 95% of its pre-outage steady state. Redynis re-replicates
    crash-wiped keys on its next due sweep; a static policy never sweeps,
    so its crashed copies stay lost (``repair_moves == 0`` by
    construction) — the contrast the drill exists to measure.
  * **blast radius** — per scheduled failure, the peak fraction of the
    keyspace left with no live replica (``blast_radius_unreachable``) and
    with no surviving replica at all (``blast_radius_wiped``), read off the
    engine's per-chunk fault telemetry.
  * **duration ladder** — the same outage at growing durations; total
    unavailability must grow monotonically with the outage length (a
    machine-independent invariant ``--fail-on-regression`` hard-gates).
  * **acceptance checks** — the ISSUE-10 criteria, recorded in the JSON and
    promoted to a hard exit by ``--fail-on-regression``:
      1. fault-off bit-exactness: ``faults=None`` and
         ``FaultConfig(enabled=False)`` produce identical ``SimResult``s
         and telemetry arrays (the off-path is structurally the PR-9
         program);
      2. redynis recovers: ``recovery_chunks`` is finite (>= 0) — the
         post-outage effective hit rate reaches 95% of its pre-outage
         steady state before the trace ends;
      3. blast radius reported: one row per scheduled failure, all finite;
      4. unavailability monotone in outage duration (the ladder);
      5. repair asymmetry: redynis repairs (``repair_moves > 0``), the
         static policies cannot (``repair_moves == 0``).

Persists ``BENCH_availability.json`` (rows + blast radius + ladder + check
verdicts). CI smoke runs a smaller trace via ``--num-requests``.
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.common import banner, emit, write_bench_json
from repro.kvsim import (
    FaultConfig,
    RedynisPolicy,
    StaticPolicy,
    TelemetryConfig,
    blast_radius_rows,
    histogram_quantile,
    region_outage,
    run_scenario,
    wan5_cluster,
    wan5_workload,
)

POLICY_ROWS = (
    ("redynis", lambda: RedynisPolicy()),
    ("static:replicated", lambda: StaticPolicy(mode="replicated")),
    ("static:remote", lambda: StaticPolicy(mode="remote")),
)
HOT_REGION = 0  # wan5_workload's heaviest region weight (0.35)


def _run(wl, cluster, policy, *, daemon_interval, seed, replay_backend,
         num_bins):
    return run_scenario(
        wl,
        cluster,
        policy,
        seed=seed,
        daemon_interval=daemon_interval,
        telemetry=TelemetryConfig(num_bins=num_bins),
        replay_backend=replay_backend,
    )


def _window_p99(trace, start: int, end: int) -> float:
    """Interpolated P99 over the outage window's summed chunk histograms."""
    return histogram_quantile(
        trace.chunk_hist[start:end].sum(axis=0), trace.edges, 0.99
    )


def _row(result, trace, *, outage_start: int, outage_end: int) -> dict:
    avail = trace.availability
    window = avail[outage_start:outage_end]
    return {
        "availability_min": float(avail.min()),
        "availability_outage_mean": float(window.mean()),
        "p99_outage_ms": _window_p99(trace, outage_start, outage_end),
        "p99_overall_ms": trace.quantile(0.99),
        "mean_latency_ms": float(result.mean_latency_ms),
        "hit_rate": float(result.hit_rate),
        "unavailable_reads": float(result.unavailable_reads),
        "unavailable_writes": float(result.unavailable_writes),
        "failovers": float(result.failovers),
        "repair_moves": float(result.repair_moves),
        "recovery_chunks": int(trace.recovery_chunks(outage_start)),
        "peak_unreachable_frac": float(trace.unreachable_frac.max()),
        "peak_wiped_frac": float(trace.wiped_frac.max()),
    }


def _check_fault_off_bitexact(wl, cluster, *, daemon_interval, seed,
                              replay_backend, num_bins) -> bool:
    """``FaultConfig(enabled=False)`` must be *the same program* as
    ``faults=None`` — bit-exact SimResult fields and telemetry arrays."""
    r_none, t_none = _run(
        wl, cluster, RedynisPolicy(), daemon_interval=daemon_interval,
        seed=seed, replay_backend=replay_backend, num_bins=num_bins,
    )
    r_off, t_off = _run(
        wl, cluster._replace(faults=FaultConfig(enabled=False)),
        RedynisPolicy(), daemon_interval=daemon_interval, seed=seed,
        replay_backend=replay_backend, num_bins=num_bins,
    )
    ok = True
    for name in r_none._fields:
        a, b = getattr(r_none, name), getattr(r_off, name)
        if not np.array_equal(np.asarray(a), np.asarray(b)):
            print(f"BITEXACT_MISMATCH,SimResult.{name},{a!r},{b!r}")
            ok = False
    for name in ("hist_group", "hit_rate", "mean_latency_ms", "moves",
                 "occupancy_bytes", "availability", "effective_hit_rate"):
        if not np.array_equal(getattr(t_none, name), getattr(t_off, name)):
            print(f"BITEXACT_MISMATCH,SimTrace.{name}")
            ok = False
    return ok


def main(
    num_requests: int = 100_000,
    num_keys: int = 1_000,
    daemon_interval: int = 200,
    read_fraction: float = 0.7,
    affinity: float = 0.8,
    seed: int = 0,
    num_bins: int = 128,
    replay_backend: str = "jax",
    fail_on_regression: bool = False,
) -> dict:
    num_chunks = (num_requests + daemon_interval - 1) // daemon_interval
    outage_start = num_chunks // 3
    outage_len = max(num_chunks // 5, 2)
    outage_end = outage_start + outage_len
    banner(
        "availability: wan5 region-outage drill "
        f"({num_requests:,} requests / {num_keys:,} keys, crash region "
        f"{HOT_REGION} chunks [{outage_start}, {outage_end}))"
    )
    wl = wan5_workload(
        num_requests=num_requests,
        num_keys=num_keys,
        read_fraction=read_fraction,
        affinity=affinity,
    )
    cluster = wan5_cluster()
    faults = region_outage(HOT_REGION, outage_start, outage_len, mode="crash")
    t_start = time.perf_counter()

    checks = {}
    checks["fault_off_bitexact"] = _check_fault_off_bitexact(
        wl, cluster, daemon_interval=daemon_interval, seed=seed,
        replay_backend=replay_backend, num_bins=num_bins,
    )

    rows, blast = {}, []
    for label, make in POLICY_ROWS:
        res, trace = _run(
            wl, cluster._replace(faults=faults), make(),
            daemon_interval=daemon_interval, seed=seed,
            replay_backend=replay_backend, num_bins=num_bins,
        )
        rows[label] = _row(
            res, trace, outage_start=outage_start, outage_end=outage_end
        )
        if label == "redynis":
            blast = blast_radius_rows(
                faults,
                num_chunks=num_chunks,
                unreachable_frac=trace.unreachable_frac,
                wiped_frac=trace.wiped_frac,
            )
        emit(
            "availability",
            round(rows[label]["availability_min"], 4),
            "availability_min",
            policy=label,
            p99_outage=round(rows[label]["p99_outage_ms"], 2),
            unavailable_reads=int(rows[label]["unavailable_reads"]),
            failovers=int(rows[label]["failovers"]),
            repair_moves=int(rows[label]["repair_moves"]),
            recovery_chunks=rows[label]["recovery_chunks"],
        )

    # Duration ladder: same outage start, growing length — total
    # unavailability is monotone in the outage duration by construction,
    # and the check is machine-independent (pure counters).
    durations = sorted({
        max(outage_len // 4, 1), max(outage_len // 2, 1), outage_len,
    })
    ladder = []
    for d in durations:
        res = run_scenario(
            wl,
            cluster._replace(
                faults=region_outage(HOT_REGION, outage_start, d)
            ),
            RedynisPolicy(), seed=seed, daemon_interval=daemon_interval,
            replay_backend=replay_backend,
        )
        ladder.append({
            "duration_chunks": int(d),
            "unavailable_total": float(
                res.unavailable_reads + res.unavailable_writes
            ),
        })
    unav = [r["unavailable_total"] for r in ladder]
    checks["unavailability_monotone_in_duration"] = bool(
        np.all(np.diff(unav) >= 0)
    )
    checks["redynis_recovers"] = rows["redynis"]["recovery_chunks"] >= 0
    checks["blast_radius_reported"] = bool(blast) and all(
        np.isfinite(r["blast_radius_unreachable"])
        and np.isfinite(r["blast_radius_wiped"])
        for r in blast
    )
    checks["repair_asymmetry"] = (
        rows["redynis"]["repair_moves"] > 0
        and rows["static:replicated"]["repair_moves"] == 0
        and rows["static:remote"]["repair_moves"] == 0
    )
    emit(
        "availability_checks",
        int(all(checks.values())),
        "all_ok",
        recovery_chunks=rows["redynis"]["recovery_chunks"],
        **{k: int(v) for k, v in checks.items()},
    )

    write_bench_json(
        "availability",
        {
            "rows": rows,
            "blast_radius": blast,
            "duration_ladder": ladder,
            "outage": {
                "kind": "region",
                "target": HOT_REGION,
                "mode": "crash",
                "start_chunk": outage_start,
                "end_chunk": outage_end,
            },
            "checks": checks,
            "wall_time_s": time.perf_counter() - t_start,
        },
        num_requests=num_requests,
        num_keys=num_keys,
        daemon_interval=daemon_interval,
        read_fraction=read_fraction,
        affinity=affinity,
        seed=seed,
        num_bins=num_bins,
        replay_backend=replay_backend,
    )
    if fail_on_regression and not all(checks.values()):
        failed = [k for k, v in checks.items() if not v]
        print(f"FAIL,availability,checks_failed={';'.join(failed)}")
        sys.exit(1)
    return {"rows": rows, "blast_radius": blast, "ladder": ladder,
            "checks": checks}


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--num-requests", type=int, default=100_000)
    ap.add_argument("--num-keys", type=int, default=1_000)
    ap.add_argument("--daemon-interval", type=int, default=200)
    ap.add_argument("--read-fraction", type=float, default=0.7)
    ap.add_argument("--affinity", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--num-bins", type=int, default=128)
    ap.add_argument(
        "--replay-backend", choices=["jax", "pallas"], default="jax",
    )
    ap.add_argument(
        "--fail-on-regression", action="store_true",
        help="exit nonzero when any acceptance check fails (fault-off "
        "bit-exactness, finite recovery, blast-radius rows, availability "
        "monotonicity, repair asymmetry)",
    )
    args = ap.parse_args()
    main(
        num_requests=args.num_requests,
        num_keys=args.num_keys,
        daemon_interval=args.daemon_interval,
        read_fraction=args.read_fraction,
        affinity=args.affinity,
        seed=args.seed,
        num_bins=args.num_bins,
        replay_backend=args.replay_backend,
        fail_on_regression=args.fail_on_regression,
    )
