"""Paper Figure 2 — Uniform Object Access Distribution.

Local / Remote / Optimized (+ beyond-paper Replicated) throughput across
read ratios 100% -> 50%, 100k requests, 3 nodes, 100 ms simulated remote
RTT, with 99% confidence intervals over repeated iterations — the exact
experiment grid of paper §8.2/§9.
"""

from __future__ import annotations

from benchmarks.common import banner, emit
from repro.kvsim import run_experiment


def main(iterations: int = 5, num_requests: int = 100_000) -> dict:
    banner("fig2: uniform object access distribution (paper Figure 2)")
    res = run_experiment(
        read_fractions=(1.0, 0.9, 0.75, 0.5),
        skewed=False,
        iterations=iterations,
        num_requests=num_requests,
    )
    for scenario, rows in res["scenarios"].items():
        for row in rows:
            emit(
                "fig2_uniform",
                round(row["throughput"], 2),
                "ops/s",
                scenario=scenario,
                read_fraction=row["read_fraction"],
                ci99=round(row["ci99"], 2),
                hit_rate=round(row["hit_rate"], 4),
            )
    # Paper §10 validation: Optimized ~10x Remote, near Local.
    opt = {r["read_fraction"]: r["throughput"] for r in res["scenarios"]["optimized"]}
    rem = {r["read_fraction"]: r["throughput"] for r in res["scenarios"]["remote"]}
    loc = {r["read_fraction"]: r["throughput"] for r in res["scenarios"]["local"]}
    for rf in opt:
        emit(
            "fig2_validation",
            round(opt[rf] / rem[rf], 2),
            "x_over_remote",
            read_fraction=rf,
            frac_of_local=round(opt[rf] / loc[rf], 3),
        )
    return res


if __name__ == "__main__":
    main()
