"""Paper Figure 2 — Uniform Object Access Distribution.

Local / Remote / Optimized (+ beyond-paper Replicated) throughput across
read ratios 100% -> 50%, 100k requests, 3 nodes, 100 ms simulated remote
RTT, with 99% confidence intervals over repeated iterations — the exact
experiment grid of paper §8.2/§9.

``engine="scan"`` (default) runs the fused lax.scan engine with the seed
dimension vmapped; ``compare_engines=True`` additionally times the retained
per-chunk reference loop on the same grid and reports the fusion speedup
(warm timings — each engine runs once to compile, then is timed).
"""

from __future__ import annotations

import time

from benchmarks.common import banner, emit, json_rows, write_bench_json
from repro.kvsim import RedynisPolicy, StaticPolicy, run_experiment

# The paper's four scenarios as policies, keyed by the figure's labels.
BASELINES = {
    "local": StaticPolicy(mode="local"),
    "remote": StaticPolicy(mode="remote"),
    "optimized": RedynisPolicy(),
    "replicated": StaticPolicy(mode="replicated"),
}


def main(
    iterations: int = 5,
    num_requests: int = 100_000,
    engine: str = "scan",
    compare_engines: bool = False,
    replay_backend: str = "jax",
) -> dict:
    banner("fig2: uniform object access distribution (paper Figure 2)")
    t_start = time.perf_counter()
    res = run_experiment(
        policies=list(BASELINES.values()),
        read_fractions=(1.0, 0.9, 0.75, 0.5),
        skewed=False,
        iterations=iterations,
        num_requests=num_requests,
        engine=engine,
        replay_backend=replay_backend,
    )
    wall_s = time.perf_counter() - t_start
    # run_experiment keys rows by resolved-policy label, in input order.
    by_name = dict(zip(BASELINES, res["policies"].values()))
    for scenario, rows in by_name.items():
        for row in rows:
            emit(
                "fig2_uniform",
                round(row["throughput"], 2),
                "ops/s",
                scenario=scenario,
                read_fraction=row["read_fraction"],
                ci99=round(row["ci99"], 2),
                hit_rate=round(row["hit_rate"], 4),
            )
    # Paper §10 validation: Optimized ~10x Remote, near Local.
    opt = {r["read_fraction"]: r["throughput"] for r in by_name["optimized"]}
    rem = {r["read_fraction"]: r["throughput"] for r in by_name["remote"]}
    loc = {r["read_fraction"]: r["throughput"] for r in by_name["local"]}
    for rf in opt:
        emit(
            "fig2_validation",
            round(opt[rf] / rem[rf], 2),
            "x_over_remote",
            read_fraction=rf,
            frac_of_local=round(opt[rf] / loc[rf], 3),
        )

    write_bench_json(
        "fig2_uniform",
        {"scenarios": json_rows(by_name), "wall_time_s": wall_s},
        engine=engine,
        iterations=iterations,
        num_requests=num_requests,
        replay_backend=replay_backend,
    )

    if compare_engines:
        banner("fig2b: scan-fusion speedup over the reference chunk loop")
        timings = {}
        kw = dict(
            policies=list(BASELINES.values()),
            iterations=iterations,
            num_requests=num_requests,
        )
        for eng in ("scan", "reference"):
            run_experiment(engine=eng, **kw)  # compile / warm caches
            t0 = time.perf_counter()
            run_experiment(engine=eng, **kw)
            timings[eng] = time.perf_counter() - t0
            emit("fig2b_engine_s", round(timings[eng], 3), "s", engine=eng)
        emit(
            "fig2b_fusion_speedup",
            round(timings["reference"] / timings["scan"], 2),
            "x",
            num_requests=num_requests,
            iterations=iterations,
        )
    return res


if __name__ == "__main__":
    main()
