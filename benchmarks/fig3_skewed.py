"""Paper Figure 3 — Skewed (zipfian 90/10) Object Access Distribution.

Same grid as Figure 2 but with the paper's skewed workload: 10% of data
items receive 90% of traffic. Adds a beyond-paper affinity sweep showing how
the Optimized scenario degrades as request sources for a key spread across
nodes (the paper's DNS-affinity assumption weakening).
"""

from __future__ import annotations

import time

from benchmarks.common import banner, emit, json_rows, write_bench_json
from repro.kvsim import (
    ClusterConfig,
    RedynisPolicy,
    StaticPolicy,
    WorkloadConfig,
    diurnal_workload,
    run_experiment,
    run_scenario,
    wan5_cluster,
    wan5_workload,
)

# The paper's four scenarios as policies, keyed by the figure's labels.
BASELINES = {
    "local": StaticPolicy(mode="local"),
    "remote": StaticPolicy(mode="remote"),
    "optimized": RedynisPolicy(),
    "replicated": StaticPolicy(mode="replicated"),
}


def main(
    iterations: int = 5,
    num_requests: int = 100_000,
    replay_backend: str = "jax",
) -> dict:
    banner("fig3: skewed (zipfian 90/10) object access (paper Figure 3)")
    t_start = time.perf_counter()
    res = run_experiment(
        policies=list(BASELINES.values()),
        read_fractions=(1.0, 0.9, 0.75, 0.5),
        skewed=True,
        iterations=iterations,
        num_requests=num_requests,
        replay_backend=replay_backend,
    )
    # run_experiment keys rows by resolved-policy label, in input order.
    by_name = dict(zip(BASELINES, res["policies"].values()))
    for scenario, rows in by_name.items():
        for row in rows:
            emit(
                "fig3_skewed",
                round(row["throughput"], 2),
                "ops/s",
                scenario=scenario,
                read_fraction=row["read_fraction"],
                ci99=round(row["ci99"], 2),
                hit_rate=round(row["hit_rate"], 4),
            )
    opt = {r["read_fraction"]: r["throughput"] for r in by_name["optimized"]}
    rem = {r["read_fraction"]: r["throughput"] for r in by_name["remote"]}
    loc = {r["read_fraction"]: r["throughput"] for r in by_name["local"]}
    for rf in opt:
        emit(
            "fig3_validation",
            round(opt[rf] / rem[rf], 2),
            "x_over_remote",
            read_fraction=rf,
            frac_of_local=round(opt[rf] / loc[rf], 3),
        )

    banner("fig3b: affinity sweep (beyond paper)")
    cluster = ClusterConfig()
    for affinity in (1.0, 0.95, 0.9, 0.8, 0.6, 1.0 / 3.0):
        wl = WorkloadConfig(
            num_requests=num_requests // 2, skewed=True, affinity=affinity
        )
        r = run_scenario(
            wl, cluster, RedynisPolicy(), seed=0,
            replay_backend=replay_backend,
        )
        emit(
            "fig3b_affinity",
            round(r.throughput_ops_s, 2),
            "ops/s",
            affinity=round(affinity, 3),
            hit_rate=round(r.hit_rate, 4),
            repl_moves=int(r.replication_moves),
        )

    banner("fig3c: 5-region WAN topology (beyond paper)")
    geo = wan5_cluster()
    wl5 = wan5_workload(num_requests=num_requests // 2)
    for label, pol in (
        ("local", StaticPolicy(mode="local")),
        ("remote", StaticPolicy(mode="remote")),
        ("optimized", RedynisPolicy()),
    ):
        r = run_scenario(wl5, geo, pol, seed=0, replay_backend=replay_backend)
        emit(
            "fig3c_wan5",
            round(r.throughput_ops_s, 2),
            "ops/s",
            scenario=label,
            hit_rate=round(r.hit_rate, 4),
            mean_latency_ms=round(r.mean_latency_ms, 2),
        )

    banner("fig3d: diurnal hot region — decay chases moving traffic")
    wld = diurnal_workload(num_requests=num_requests // 2)
    for decay in (1.0, 0.5):
        r = run_scenario(
            wld, geo, RedynisPolicy(decay=decay), seed=0,
            replay_backend=replay_backend,
        )
        emit(
            "fig3d_diurnal",
            round(r.throughput_ops_s, 2),
            "ops/s",
            decay=decay,
            hit_rate=round(r.hit_rate, 4),
            repl_moves=int(r.replication_moves),
        )
    write_bench_json(
        "fig3_skewed",
        {
            "scenarios": json_rows(by_name),
            "wall_time_s": time.perf_counter() - t_start,
        },
        iterations=iterations,
        num_requests=num_requests,
        replay_backend=replay_backend,
    )
    return res


if __name__ == "__main__":
    main()
